"""Deterministic, resumable data pipeline.

State is (seed, step) — nothing else. batch(step) is a pure function, so a
restart resumes bit-exactly from any checkpointed step, and any host in a
multi-pod job can materialize exactly its shard of the batch (no data server
required for the synthetic source; a real corpus source would key
shard-by-(step, host) the same way).

Poisson subsampling (the DP-SGD sampling scheme the RDP accountant assumes)
is provided as a fixed-capacity variant: each step draws inclusion mask ~
Bernoulli(q) over a window and pads/truncates to the physical batch with a
loss-mask column.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.synthetic import batch_spec, make_batch


@dataclass(frozen=True)
class PipelineConfig:
    batch: int
    seq_len: int
    seed: int = 0
    poisson_q: float = 0.0   # 0 = fixed-size sampling


class Pipeline:
    def __init__(self, model_cfg: ModelConfig, cfg: PipelineConfig):
        self.model_cfg = model_cfg
        self.cfg = cfg

    def spec(self):
        return batch_spec(self.model_cfg, self.cfg.batch, self.cfg.seq_len)

    def state_dict(self) -> dict:
        """Restorable pipeline state for the RunState checkpoint. Because
        ``batch(step)`` is a pure function, the cursor is the train step the
        caller already persists — what must round-trip here is the
        GENERATIVE config, so a resumed run that would silently produce
        different batches (different seed / batch / sampling) is caught."""
        return {"seed": self.cfg.seed, "batch": self.cfg.batch,
                "seq_len": self.cfg.seq_len,
                "poisson_q": self.cfg.poisson_q}

    def load_state(self, state: dict) -> None:
        """Validate that this pipeline continues the checkpointed stream;
        raises on drift (a changed seed or batch size re-samples the data,
        voiding both bitwise resume parity and the accounted sample rate)."""
        mine = self.state_dict()
        drift = {k: (state.get(k), mine[k]) for k in mine
                 if state.get(k) != mine[k]}
        if drift:
            raise ValueError(
                "data-pipeline state drift between checkpoint and resumed "
                "run (checkpointed != configured): "
                + ", ".join(f"{k}: {a!r} != {b!r}"
                            for k, (a, b) in sorted(drift.items())))

    def batch(self, step: int) -> dict:
        b = make_batch(self.model_cfg, self.cfg.batch, self.cfg.seq_len,
                       seed=self.cfg.seed, step=step)
        if self.cfg.poisson_q > 0.0:
            rng = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed), step),
                0xD1CE)
            tokens = b["tokens"]
            include = (jax.random.uniform(rng, (tokens.shape[0],))
                       < self.cfg.poisson_q)
            mask = jnp.broadcast_to(include[:, None], tokens.shape)
            b = dict(b, mask=mask.astype(jnp.float32))
        return b

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
