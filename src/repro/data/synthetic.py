"""Synthetic batch construction — one source of truth for both real arrays
(tests / examples / training) and ShapeDtypeStruct stand-ins (dry-run).

Counter-based determinism: batch(step) depends only on (seed, step), so the
pipeline resumes exactly after checkpoint restore with no iterator state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def batch_spec(cfg: ModelConfig, B: int, T: int, dtype="float32") -> dict:
    """ShapeDtypeStructs for a training batch of this architecture."""
    sd = jax.ShapeDtypeStruct
    spec = {"tokens": sd((B, T), jnp.int32)}
    if cfg.family == "vlm":
        spec["patches"] = sd((B, cfg.patch_tokens, cfg.vit_dim), jnp.dtype(dtype))
    if cfg.family == "encdec":
        # seq_len is interpreted as encoder audio frames; decoder is fixed-len
        spec = {"frames": sd((B, T, cfg.frame_dim), jnp.dtype(dtype)),
                "tokens": sd((B, cfg.decoder_len), jnp.int32)}
    return spec


def make_batch(cfg: ModelConfig, B: int, T: int, seed: int = 0,
               step: int = 0, dtype="float32") -> dict:
    """Concrete random batch matching batch_spec."""
    rng = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    ks = jax.random.split(rng, 3)
    spec = batch_spec(cfg, B, T, dtype)
    out = {}
    for i, (name, s) in enumerate(sorted(spec.items())):
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(ks[i % 3], s.shape, 0, cfg.vocab,
                                           dtype=s.dtype)
        else:
            out[name] = jax.random.normal(ks[i % 3], s.shape, s.dtype)
    return out


def decode_spec(model, cfg: ModelConfig, B: int, S: int, dtype=None) -> dict:
    """ShapeDtypeStructs for (cache, tokens, pos) of a decode step."""
    cache = jax.eval_shape(lambda: model.init_cache(B, S, dtype=dtype))
    return {"cache": cache,
            "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}
