"""Synthetic batch construction — one source of truth for both real arrays
(tests / examples / training) and ShapeDtypeStruct stand-ins (dry-run).

Counter-based determinism: batch(step) depends only on (seed, step), so the
pipeline resumes exactly after checkpoint restore with no iterator state.

Token sequences are a LEARNABLE synthetic language, not i.i.d. uniform
noise: each sequence is an incrementing run (next = cur + 1 mod vocab) from
a random start, with a fraction of positions replaced by uniform outliers.
I.i.d. uniform tokens gave training loops literally nothing to learn — the
loss could only drift around ln(vocab) + the init's logit-variance penalty,
which is what plateaued the end-to-end train test. The run structure keeps
every batch fresh (no fixed dataset to memorize, resume semantics
unchanged) while giving optimizers a stationary signal that shows up in the
loss within a handful of steps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def batch_spec(cfg: ModelConfig, B: int, T: int, dtype="float32") -> dict:
    """ShapeDtypeStructs for a training batch of this architecture."""
    sd = jax.ShapeDtypeStruct
    spec = {"tokens": sd((B, T), jnp.int32)}
    if cfg.family == "vlm":
        spec["patches"] = sd((B, cfg.patch_tokens, cfg.vit_dim), jnp.dtype(dtype))
    if cfg.family == "encdec":
        # seq_len is interpreted as encoder audio frames; decoder is fixed-len
        spec = {"frames": sd((B, T, cfg.frame_dim), jnp.dtype(dtype)),
                "tokens": sd((B, cfg.decoder_len), jnp.int32)}
    return spec


OUTLIER_FRAC = 0.15   # per-position probability of a uniform-random token


def structured_tokens(rng, B: int, T: int, vocab: int,
                      outlier_frac: float = OUTLIER_FRAC):
    """(B, T) int32 learnable sequences: incrementing runs mod vocab from
    random starts, with ``outlier_frac`` of positions replaced by uniform
    tokens (irreducible next-token entropy, keeps the task non-trivial)."""
    k_start, k_mask, k_rare = jax.random.split(rng, 3)
    runs = (jnp.arange(T)[None, :]
            + jax.random.randint(k_start, (B, 1), 0, vocab)) % vocab
    rare = jax.random.randint(k_rare, (B, T), 0, vocab)
    keep_run = jax.random.uniform(k_mask, (B, T)) >= outlier_frac
    return jnp.where(keep_run, runs, rare).astype(jnp.int32)


def make_batch(cfg: ModelConfig, B: int, T: int, seed: int = 0,
               step: int = 0, dtype="float32") -> dict:
    """Concrete random batch matching batch_spec."""
    rng = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    ks = jax.random.split(rng, 3)
    spec = batch_spec(cfg, B, T, dtype)
    out = {}
    for i, (name, s) in enumerate(sorted(spec.items())):
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = structured_tokens(ks[i % 3], *s.shape, cfg.vocab)
        else:
            out[name] = jax.random.normal(ks[i % 3], s.shape, s.dtype)
    return out


def decode_spec(model, cfg: ModelConfig, B: int, S: int, dtype=None) -> dict:
    """ShapeDtypeStructs for (cache, tokens, pos) of a decode step."""
    cache = jax.eval_shape(lambda: model.init_cache(B, S, dtype=dtype))
    return {"cache": cache,
            "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}
