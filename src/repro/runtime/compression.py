"""Gradient compression for the slow cross-pod (DCN) axis.

8-bit stochastic-rounding quantization with per-tensor scale. The cross-pod
gradient reduction is implemented as all_gather(int8 + scale) + local
dequant-sum instead of a bf16 all-reduce: wire bytes per pod go from
2*|G|*2 (all-reduce bf16, bidirectional) to n_pods*|G| (gathered int8) —
a 4x reduction at n_pods=2. XLA collectives are dtype-preserving, so this is
expressible today without custom DCN collectives.

DP note: quantization is applied AFTER per-sample clipping + noise, so the
privacy guarantee is untouched (post-processing invariance of DP); stochastic
rounding keeps the gradient unbiased.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x, rng):
    """-> (int8 values, f32 scale). Stochastic rounding (unbiased)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    y = x32 / scale
    lo = jnp.floor(y)
    frac = y - lo
    up = jax.random.uniform(rng, x.shape) < frac
    q = jnp.clip(lo + up.astype(jnp.float32), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale, dtype=jnp.float32):
    """``scale`` may be a scalar or a leading-axes tensor (e.g. the (L,)
    per-layer scales a scan stacks for the activation tape)."""
    scale = jnp.asarray(scale)
    if scale.ndim:
        scale = scale.reshape(scale.shape + (1,) * (q.ndim - scale.ndim))
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_allreduce_mean(x, rng, axis_name: str):
    """Mean over `axis_name` via quantized all_gather + local dequant-sum.

    Call inside shard_map/pjit with `axis_name` bound to the pod axis."""
    q, scale = quantize(x, rng)
    qg = jax.lax.all_gather(q, axis_name)            # (n, ...) int8 on wire
    sg = jax.lax.all_gather(scale, axis_name)        # (n,) f32
    n = qg.shape[0]
    summed = jnp.tensordot(sg.astype(jnp.float32),
                           qg.astype(jnp.float32), axes=1)
    return (summed / n).astype(x.dtype)


def compressed_tree_allreduce_mean(tree, rng, axis_name: str):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    rngs = jax.random.split(rng, len(leaves))
    out = [compressed_allreduce_mean(x, r, axis_name)
           for x, r in zip(leaves, rngs)]
    return jax.tree_util.tree_unflatten(treedef, out)
