"""Fault-tolerance runtime: preemption handling, heartbeat / straggler
monitoring, and the restart protocol.

On a 1000+-node deployment the failure model is: (a) SIGTERM preemption with
a grace window, (b) silent host hangs (straggler -> collective timeout),
(c) hard crashes. The strategy is checkpoint/restart: every host runs the
same SPMD program; any failure triggers a job-level restart which resumes
from the latest valid checkpoint (atomic, checksummed — see
repro.checkpoint). The data pipeline is counter-based so resume is
bit-exact. Elastic re-scale: checkpoints are sharding-agnostic, so the
restarted job may use a different mesh (fewer/more pods) — restore() applies
the new shardings.
"""
from __future__ import annotations

import signal
import threading
import time
from dataclasses import dataclass, field

import jax

from repro.checkpoint import checkpoint as ckpt


class PreemptionGuard:
    """Installs a SIGTERM/SIGINT handler that flips a flag; the train loop
    polls should_stop() once per step and checkpoints before exiting."""

    def __init__(self, install: bool = True):
        self._stop = threading.Event()
        if install:
            try:
                signal.signal(signal.SIGTERM, self._handler)
            except ValueError:  # not main thread (tests)
                pass

    def _handler(self, signum, frame):
        self._stop.set()

    def request_stop(self):
        self._stop.set()

    def should_stop(self) -> bool:
        return self._stop.is_set()


@dataclass(frozen=True)
class StallReport:
    """Structured stall diagnosis handed to ``Heartbeat.on_stall`` — enough
    for a supervisor to log/page on without reaching back into the
    watchdog: which step last made progress, how stale it is, what the
    compute backend was, and the configured patience."""
    last_step: int
    seconds_since_beat: float
    timeout_s: float
    backend: str

    def describe(self) -> str:
        return (f"stall: no step since step {self.last_step} for "
                f"{self.seconds_since_beat:.0f}s "
                f"(timeout {self.timeout_s:.0f}s, backend {self.backend})")


class Heartbeat:
    """Step-progress watchdog (straggler / hang detection).

    The train loop calls beat(step) after every step. A daemon thread checks
    that beats keep arriving within `timeout_s`; on expiry it invokes
    `on_stall` with a :class:`StallReport` (the train driver requests a
    graceful stop so the loop force-checkpoints before exit; a pod-level
    supervisor would escalate to restart, which is the only sound straggler
    mitigation in a synchronous SPMD collective world)."""

    def __init__(self, timeout_s: float = 300.0, on_stall=None, poll_s=None):
        self.timeout_s = timeout_s
        self.on_stall = on_stall or (lambda report: None)
        self._last = time.monotonic()
        self._step = -1
        self.stalled = False
        self._stop = threading.Event()
        self._poll = poll_s or min(5.0, timeout_s / 4)
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def beat(self, step: int):
        self._step = step
        self._last = time.monotonic()
        self.stalled = False

    def _report(self) -> StallReport:
        try:
            backend = jax.default_backend()
        except Exception:   # backend teardown during interpreter exit
            backend = "unknown"
        return StallReport(last_step=self._step,
                           seconds_since_beat=time.monotonic() - self._last,
                           timeout_s=self.timeout_s, backend=backend)

    def _run(self):
        while not self._stop.wait(self._poll):
            if time.monotonic() - self._last > self.timeout_s:
                self.stalled = True
                self.on_stall(self._report())

    def close(self):
        self._stop.set()
        self._thread.join(timeout=1.0)


@dataclass
class CheckpointManager:
    """Policy wrapper: save every N steps + on preemption; resume latest.

    ``maybe_save`` is ASYNC-sliced: the device-side copy of every
    addressable shard happens synchronously (the caller donates its state
    into the next step immediately after — see checkpoint.shard_snapshot's
    copy-before-donate contract), while the device->host transfer, npz
    write, fsyncs and the atomic commit run on a background thread. Only
    one write is in flight at a time; a new save (or ``wait``/``resume``)
    joins the previous one first."""

    root: str
    every: int = 100
    keep: int = 3
    async_save: bool = True
    _pending: threading.Thread = field(default=None, repr=False)

    def maybe_save(self, step: int, state, force: bool = False,
                   meta: dict = None):
        if not force and (self.every <= 0 or step % self.every != 0):
            return False
        self.wait()
        slices = ckpt.shard_snapshot(state)  # sync: copy-before-donate
        if self.async_save and not force:
            self._pending = threading.Thread(
                target=ckpt.save, args=(self.root, step, slices, self.keep),
                kwargs={"meta": meta})
            self._pending.start()
        else:
            ckpt.save(self.root, step, slices, self.keep, meta=meta)
        return True

    def wait(self):
        if self._pending is not None and self._pending.is_alive():
            self._pending.join()
        self._pending = None

    def resume(self, template=None, shardings=None):
        """-> (state, step, meta) from the latest valid checkpoint, or
        (None, -1, {})."""
        self.wait()
        if ckpt.latest_step(self.root) is None:
            return None, -1, {}
        return ckpt.restore(self.root, template=template,
                            shardings=shardings)
