"""Deterministic fault injection for crash/restart testing.

Production resilience claims are only as good as the failures they were
tested against, so the train driver and the checkpoint writer carry named
*fault sites* — `maybe_fault(site, step=...)` calls that are no-ops unless
the `REPRO_FAULT` environment variable requests a fault:

    REPRO_FAULT="<site>[@<step>][:<action>]"

Sites instrumented today:

  step             top of the train loop, before dispatching step N
                   (``@N`` pins the firing step)
  ckpt_mid_write   checkpoint.save: the shard payload is on disk but the
                   manifest is NOT — a torn write that the crash-atomic
                   commit protocol must leave invisible
  ckpt_pre_commit  checkpoint.save: payload + manifest written, the
                   tmp-dir -> final rename has NOT happened

Actions:

  sigkill   SIGKILL to self — a hard crash; nothing runs afterwards, the
            process dies with -SIGKILL (the scheduler-preemption /
            OOM-killer model). This is the default.
  sigterm   SIGTERM to self — graceful preemption; the signal returns to
            the caller and :class:`repro.runtime.fault_tolerance
            .PreemptionGuard`'s handler flips its stop flag, so the loop
            checkpoints and exits through the normal path.
  exit      ``os._exit(FAULT_EXIT_CODE)`` — hard exit without signal
            delivery (no atexit, no flush), for runtimes where SIGKILL is
            awkward to observe.

The env-var channel makes subprocess fault tests one line: run the exact
production command with ``REPRO_FAULT=step@7`` and assert the recovery.
``run_subprocess`` wraps the spawn + death-mode assertion for tests.
"""
from __future__ import annotations

import os
import signal
import subprocess
import sys
from dataclasses import dataclass
from typing import Optional

ENV_VAR = "REPRO_FAULT"
FAULT_EXIT_CODE = 113
ACTIONS = ("sigkill", "sigterm", "exit")


@dataclass(frozen=True)
class FaultSpec:
    """One requested fault: fire at ``site`` (optionally pinned to a train
    step) with ``action``."""
    site: str
    step: Optional[int] = None
    action: str = "sigkill"

    def encode(self) -> str:
        s = self.site
        if self.step is not None:
            s += f"@{self.step}"
        return f"{s}:{self.action}"


def parse_fault(text: str) -> Optional[FaultSpec]:
    """``"site[@step][:action]"`` -> FaultSpec; ''/None -> None."""
    if not text:
        return None
    text = text.strip()
    action = "sigkill"
    if ":" in text:
        text, action = text.rsplit(":", 1)
    if action not in ACTIONS:
        raise ValueError(f"unknown fault action {action!r}; options: "
                         f"{ACTIONS}")
    step = None
    if "@" in text:
        text, step_s = text.rsplit("@", 1)
        step = int(step_s)
    if not text:
        raise ValueError("fault spec needs a site name")
    return FaultSpec(site=text, step=step, action=action)


def active_fault() -> Optional[FaultSpec]:
    """The fault requested by the environment, re-read on every call (tests
    flip it between phases of a single process)."""
    return parse_fault(os.environ.get(ENV_VAR, ""))


def _fire(spec: FaultSpec) -> None:
    if spec.action == "sigkill":
        os.kill(os.getpid(), signal.SIGKILL)
    elif spec.action == "sigterm":
        # returns: the installed handler (PreemptionGuard) flips its flag
        # and the caller proceeds into the graceful-shutdown path
        os.kill(os.getpid(), signal.SIGTERM)
    else:
        os._exit(FAULT_EXIT_CODE)


def maybe_fault(site: str, step: Optional[int] = None) -> bool:
    """Fire the environment's requested fault if it matches this site (and
    step, when the spec pins one). Returns True when a returning action
    (sigterm) fired; never returns for sigkill/exit."""
    spec = active_fault()
    if spec is None or spec.site != site:
        return False
    if spec.step is not None and step != spec.step:
        return False
    _fire(spec)
    return True


# ------------------------------------------------------------ test harness
def expected_death(spec: FaultSpec) -> tuple:
    """Return codes a process killed by ``spec`` may report."""
    if spec.action == "sigkill":
        return (-signal.SIGKILL, 128 + signal.SIGKILL)
    if spec.action == "exit":
        return (FAULT_EXIT_CODE,)
    return (0,)  # sigterm: graceful checkpoint-and-exit path


def run_subprocess(code: str, fault: Optional[FaultSpec] = None,
                   env: Optional[dict] = None, timeout: int = 600,
                   cwd: Optional[str] = None) -> subprocess.CompletedProcess:
    """Run ``python -c code`` with an optional injected fault.

    With a fault whose action kills the process, asserts the subprocess
    died the expected way (a run that survives its own crash test is a
    broken test); without one, asserts it exited 0."""
    run_env = dict(os.environ)
    if env:
        run_env.update(env)
    run_env.pop(ENV_VAR, None)
    if fault is not None:
        run_env[ENV_VAR] = fault.encode()
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=run_env, timeout=timeout, cwd=cwd)
    ok = (0,) if fault is None else expected_death(fault)
    if r.returncode not in ok:
        raise AssertionError(
            f"subprocess exited {r.returncode}, expected one of {ok}\n"
            f"--- stdout ---\n{r.stdout[-2000:]}\n"
            f"--- stderr ---\n{r.stderr[-4000:]}")
    return r
