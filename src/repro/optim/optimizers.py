"""Pure-JAX optimizers (no optax in this environment): SGD(m), Adam(W),
LAMB, Adafactor. Functional API:

    opt = make_optimizer("adamw", lr=..., weight_decay=...)
    state = opt.init(params)
    params, state = opt.update(grads, state, params, step)

States are pytrees mirroring params (sharding follows params under pjit).
Adafactor keeps factored second moments — the memory-frugal choice for the
405B configs (optimizer state bytes dominate HBM there; see EXPERIMENTS).

``update_leaves`` is the fused-update entry: instead of a materialized
gradient tree it takes ``grad_for(path, param) -> grad leaf`` and walks the
leaves ONCE, producing each gradient (e.g. clipped sum + shard-local noise,
``core.policy.noise_leaf_fn``) immediately before its update — so a second
full-parameter-size gradient copy is never live next to the optimizer
state. ``update`` keeps the classic materialized-tree contract.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.utils.tree import flatten, unflatten

F32 = jnp.float32


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, step) -> (new_params, new_state)
    # (grad_for, state, params, step) -> (new_params, new_state); None when
    # the optimizer has no fused path (callers fall back to update)
    update_leaves: Optional[Callable] = None


def _tmap(fn, *trees):
    return jax.tree_util.tree_map(fn, *trees)


def _materialized(update_leaves) -> Callable:
    """The classic update contract as a delegate: one body per optimizer
    (update_leaves), so the fused and materialized paths cannot diverge."""

    def update(grads, state, params, step):
        fg = flatten(grads)
        return update_leaves(lambda path, p: fg[path], state, params, step)

    return update


# ---------------------------------------------------------------------- sgd
def sgd(lr_fn, momentum: float = 0.9, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"m": _tmap(lambda p: jnp.zeros_like(p, F32), params)}

    def update_leaves(grad_for, state, params, step):
        lr = lr_fn(step)
        fp, fm = flatten(params), flatten(state["m"])
        new_p, new_m = {}, {}
        for path, p in fp.items():
            m_ = momentum * fm[path] + grad_for(path, p).astype(F32)
            new_m[path] = m_
            new_p[path] = (p.astype(F32) - lr * (m_ + weight_decay
                           * p.astype(F32))).astype(p.dtype)
        return unflatten(new_p), {"m": unflatten(new_m)}

    return Optimizer(init, _materialized(update_leaves), update_leaves)


# --------------------------------------------------------------------- adam
def adamw(lr_fn, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, F32)
        return {"m": _tmap(z, params), "v": _tmap(z, params)}

    def update_leaves(grad_for, state, params, step):
        lr = lr_fn(step)
        t = step.astype(F32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        fp = flatten(params)
        fm, fv = flatten(state["m"]), flatten(state["v"])
        new_p, new_m, new_v = {}, {}, {}
        for path, p in fp.items():
            g = grad_for(path, p).astype(F32)
            m_ = b1 * fm[path] + (1 - b1) * g
            v_ = b2 * fv[path] + (1 - b2) * jnp.square(g)
            new_m[path], new_v[path] = m_, v_
            step_ = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            new_p[path] = (p.astype(F32) - lr * (step_ + weight_decay
                           * p.astype(F32))).astype(p.dtype)
        return unflatten(new_p), {"m": unflatten(new_m),
                                  "v": unflatten(new_v)}

    return Optimizer(init, _materialized(update_leaves), update_leaves)


# --------------------------------------------------------------------- lamb
def lamb(lr_fn, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-6,
         weight_decay: float = 0.01) -> Optimizer:
    base = adamw(lambda s: 1.0, b1, b2, eps, 0.0)

    def init(params):
        return base.init(params)

    def update_leaves(grad_for, state, params, step):
        lr = lr_fn(step)
        t = step.astype(F32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        fp = flatten(params)
        fm, fv = flatten(state["m"]), flatten(state["v"])
        new_p, new_m, new_v = {}, {}, {}
        for path, p in fp.items():
            g = grad_for(path, p).astype(F32)
            m_ = b1 * fm[path] + (1 - b1) * g
            v_ = b2 * fv[path] + (1 - b2) * jnp.square(g)
            new_m[path], new_v[path] = m_, v_
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps) \
                + weight_decay * p.astype(F32)
            pn = jnp.sqrt(jnp.sum(jnp.square(p.astype(F32))))
            un = jnp.sqrt(jnp.sum(jnp.square(u)))
            trust = jnp.where((pn > 0) & (un > 0), pn / un, 1.0)
            new_p[path] = (p.astype(F32) - lr * trust * u).astype(p.dtype)
        return unflatten(new_p), {"m": unflatten(new_m),
                                  "v": unflatten(new_v)}

    return Optimizer(init, _materialized(update_leaves), update_leaves)


# ---------------------------------------------------------------- adafactor
def adafactor(lr_fn, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0) -> Optimizer:
    """Factored second moments for >=2D params: O(d+p) state instead of O(dp)."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def z(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], F32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], F32)}
            return {"v": jnp.zeros_like(p, F32)}

        return {"s": _tmap(z, params)}

    def update_leaves(grad_for, state, params, step):
        lr = lr_fn(step)
        t = step.astype(F32) + 1.0
        beta = 1.0 - jnp.power(t, -decay)
        fp = flatten(params)
        fs = flatten(state["s"])  # leaf paths: <param>/vr|vc or <param>/v

        def upd(p, g, s):
            g = g.astype(F32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = (vr[..., None] / jnp.mean(vr, axis=-1, keepdims=True)[..., None]
                         ) * vc[..., None, :]
                u = g * jax.lax.rsqrt(denom + eps)
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                ns = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            newp = (p.astype(F32) - lr * (u + weight_decay * p.astype(F32))
                    ).astype(p.dtype)
            return newp, ns

        new_p, new_s = {}, {}
        for path, p in fp.items():
            s = ({"vr": fs[path + "/vr"], "vc": fs[path + "/vc"]}
                 if _factored(p) else {"v": fs[path + "/v"]})
            new_p[path], ns = upd(p, grad_for(path, p), s)
            for k, v in ns.items():
                new_s[path + "/" + k] = v
        return unflatten(new_p), {"s": unflatten(new_s)}

    return Optimizer(init, _materialized(update_leaves), update_leaves)


# ----------------------------------------------------------------- registry
def make_optimizer(name: str, lr_fn, weight_decay: float = 0.0,
                   **kw) -> Optimizer:
    """``kw`` passes optimizer-specific knobs through (e.g. DP-FTRL's
    ``momentum`` / ``restart_every``)."""
    if name == "sgd":
        return sgd(lr_fn, weight_decay=weight_decay, **kw)
    if name == "adamw":
        return adamw(lr_fn, weight_decay=weight_decay, **kw)
    if name == "lamb":
        return lamb(lr_fn, weight_decay=weight_decay, **kw)
    if name == "adafactor":
        return adafactor(lr_fn, weight_decay=weight_decay, **kw)
    if name == "ftrl":
        from repro.optim.ftrl import ftrl
        return ftrl(lr_fn, weight_decay=weight_decay, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
