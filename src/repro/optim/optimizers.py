"""Pure-JAX optimizers (no optax in this environment): SGD(m), Adam(W),
LAMB, Adafactor. Functional API:

    opt = make_optimizer("adamw", lr=..., weight_decay=...)
    state = opt.init(params)
    params, state = opt.update(grads, state, params, step)

States are pytrees mirroring params (sharding follows params under pjit).
Adafactor keeps factored second moments — the memory-frugal choice for the
405B configs (optimizer state bytes dominate HBM there; see EXPERIMENTS).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, step) -> (new_params, new_state)


def _tmap(fn, *trees):
    return jax.tree_util.tree_map(fn, *trees)


# ---------------------------------------------------------------------- sgd
def sgd(lr_fn, momentum: float = 0.9, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"m": _tmap(lambda p: jnp.zeros_like(p, F32), params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)
        m = _tmap(lambda m_, g: momentum * m_ + g.astype(F32), state["m"], grads)
        new_p = _tmap(lambda p, m_: (p.astype(F32) - lr * (m_ + weight_decay
                      * p.astype(F32))).astype(p.dtype), params, m)
        return new_p, {"m": m}

    return Optimizer(init, update)


# --------------------------------------------------------------------- adam
def adamw(lr_fn, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, F32)
        return {"m": _tmap(z, params), "v": _tmap(z, params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = step.astype(F32) + 1.0
        m = _tmap(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(F32),
                  state["m"], grads)
        v = _tmap(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(F32)),
                  state["v"], grads)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(p, m_, v_):
            step_ = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            return (p.astype(F32) - lr * (step_ + weight_decay * p.astype(F32))
                    ).astype(p.dtype)

        return _tmap(upd, params, m, v), {"m": m, "v": v}

    return Optimizer(init, update)


# --------------------------------------------------------------------- lamb
def lamb(lr_fn, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-6,
         weight_decay: float = 0.01) -> Optimizer:
    base = adamw(lambda s: 1.0, b1, b2, eps, 0.0)

    def init(params):
        return base.init(params)

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = step.astype(F32) + 1.0
        m = _tmap(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(F32),
                  state["m"], grads)
        v = _tmap(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(F32)),
                  state["v"], grads)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps) + weight_decay * p.astype(F32)
            pn = jnp.sqrt(jnp.sum(jnp.square(p.astype(F32))))
            un = jnp.sqrt(jnp.sum(jnp.square(u)))
            trust = jnp.where((pn > 0) & (un > 0), pn / un, 1.0)
            return (p.astype(F32) - lr * trust * u).astype(p.dtype)

        return _tmap(upd, params, m, v), {"m": m, "v": v}

    return Optimizer(init, update)


# ---------------------------------------------------------------- adafactor
def adafactor(lr_fn, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0) -> Optimizer:
    """Factored second moments for >=2D params: O(d+p) state instead of O(dp)."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def z(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], F32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], F32)}
            return {"v": jnp.zeros_like(p, F32)}

        return {"s": _tmap(z, params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = step.astype(F32) + 1.0
        beta = 1.0 - jnp.power(t, -decay)

        def upd(p, g, s):
            g = g.astype(F32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = (vr[..., None] / jnp.mean(vr, axis=-1, keepdims=True)[..., None]
                         ) * vc[..., None, :]
                u = g * jax.lax.rsqrt(denom + eps)
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                ns = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            newp = (p.astype(F32) - lr * (u + weight_decay * p.astype(F32))
                    ).astype(p.dtype)
            return newp, ns

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        flat_s = tdef.flatten_up_to(state["s"])
        outs = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
        new_s = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
        return new_p, {"s": new_s}

    return Optimizer(init, update)


# ----------------------------------------------------------------- registry
def make_optimizer(name: str, lr_fn, weight_decay: float = 0.0,
                   **kw) -> Optimizer:
    """``kw`` passes optimizer-specific knobs through (e.g. DP-FTRL's
    ``momentum`` / ``restart_every``)."""
    if name == "sgd":
        return sgd(lr_fn, weight_decay=weight_decay, **kw)
    if name == "adamw":
        return adamw(lr_fn, weight_decay=weight_decay, **kw)
    if name == "lamb":
        return lamb(lr_fn, weight_decay=weight_decay, **kw)
    if name == "adafactor":
        return adafactor(lr_fn, weight_decay=weight_decay, **kw)
    if name == "ftrl":
        from repro.optim.ftrl import ftrl
        return ftrl(lr_fn, weight_decay=weight_decay, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
