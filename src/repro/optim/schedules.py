"""LR schedules as step -> lr functions (jit-traceable)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(lr: float, warmup: int, total: int, final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        w = jnp.maximum(1.0, float(warmup))
        warm = lr * jnp.minimum(1.0, (s + 1.0) / w)
        prog = jnp.clip((s - warmup) / jnp.maximum(1.0, total - warmup), 0.0, 1.0)
        cos = lr * (final_frac + (1 - final_frac) * 0.5 *
                    (1.0 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup, warm, cos)

    return fn


def warmup_linear(lr: float, warmup: int, total: int):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = lr * jnp.minimum(1.0, (s + 1.0) / jnp.maximum(1.0, float(warmup)))
        decay = lr * jnp.clip(1.0 - (s - warmup) / jnp.maximum(1.0, total - warmup),
                              0.0, 1.0)
        return jnp.where(s < warmup, warm, decay)

    return fn


def make_schedule(name: str, lr: float, warmup: int = 0, total: int = 1):
    if name == "constant" or warmup == 0 and name == "":
        return constant(lr)
    if name == "cosine":
        return warmup_cosine(lr, warmup, total)
    if name == "linear":
        return warmup_linear(lr, warmup, total)
    return constant(lr)
