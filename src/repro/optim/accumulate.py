"""Gradient accumulation with DP semantics (paper footnote 2): the LOGICAL
batch determines accuracy and privacy accounting; the PHYSICAL (micro) batch
only determines memory. Per-sample clipping happens inside each microbatch;
the clipped sums accumulate across microbatches in a lax.scan; noise is added
ONCE per logical batch via the policy's mechanism (per clip unit:
sigma * sigma_scale_u * composed sensitivity; tree-aggregation increments
when the policy runs DP-FTRL noise — ``step`` threads through for that).
Accepts a DPConfig or a PrivacyPolicy."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bk import bk_clipped_sum
from repro.core.policy import as_policy, finalize_noise, resolve_policy
from repro.utils.tree import flatten, unflatten


def accumulated_baseline_grad(apply_fn, params, batch, rng, cfg,
                              microbatch: int, step=None):
    """Microbatched accumulation for the non-BK modes (nonprivate /
    ghostclip / opacus / ...): per-microbatch grads are re-scaled to sums,
    accumulated under lax.scan, then noised once (DP modes)."""
    import dataclasses

    from repro.core.engine import make_grad_fn

    policy = as_policy(cfg)
    B = jax.tree_util.tree_leaves(batch)[0].shape[0]
    mb_policy = (policy if policy.mode == "nonprivate"
                 else dataclasses.replace(policy, sigma=0.0))
    grad_fn = make_grad_fn(apply_fn, mb_policy)
    if microbatch <= 0 or microbatch >= B:
        return make_grad_fn(apply_fn, policy)(params, batch, rng, step)
    assert B % microbatch == 0, (B, microbatch)
    M = B // microbatch
    mb_batch = jax.tree_util.tree_map(
        lambda x: x.reshape((M, microbatch) + x.shape[1:]), batch)
    g0 = jax.eval_shape(
        lambda p, b: grad_fn(p, b, rng)[0], params,
        jax.tree_util.tree_map(lambda x: jax.ShapeDtypeStruct(x.shape[1:],
                                                              x.dtype),
                               mb_batch))
    zeros = jax.tree_util.tree_map(lambda v: jnp.zeros(v.shape, v.dtype), g0)

    def body(acc, mb):
        g, aux = grad_fn(params, mb, rng)
        acc = jax.tree_util.tree_map(
            lambda a, x: a + x.astype(a.dtype) * float(microbatch), acc, g)
        return acc, aux["loss"]

    sums, losses = jax.lax.scan(body, zeros, mb_batch)
    if policy.mode == "nonprivate":
        grads = jax.tree_util.tree_map(lambda s: s / float(B), sums)
    else:
        res = resolve_policy(policy, flatten(params))
        flat = finalize_noise(policy, res, flatten(sums), rng, float(B), step)
        grads = unflatten(flat)
    return grads, {"loss": jnp.mean(losses)}


def accumulated_private_grad(apply_fn, params, batch, rng, cfg,
                             microbatch: int, step=None):
    """batch leaves (B_logical, ...); microbatch must divide B_logical.
    Returns (grads, aux) identical in distribution to the full-batch BK call."""
    from repro.core.bk import BK_MODES

    policy = as_policy(cfg)
    if policy.mode not in BK_MODES:
        return accumulated_baseline_grad(apply_fn, params, batch, rng, policy,
                                         microbatch, step)
    B = jax.tree_util.tree_leaves(batch)[0].shape[0]
    if microbatch <= 0 or microbatch >= B:
        from repro.core.bk import bk_private_grad
        return bk_private_grad(apply_fn, params, batch, rng, policy, step)
    assert B % microbatch == 0, (B, microbatch)
    M = B // microbatch
    mb_batch = jax.tree_util.tree_map(
        lambda x: x.reshape((M, microbatch) + x.shape[1:]), batch)

    sums0, aux0 = jax.eval_shape(
        lambda p, b: bk_clipped_sum(apply_fn, p, b, policy), params,
        jax.tree_util.tree_map(lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                               mb_batch))
    zeros = {k: jnp.zeros(v.shape, v.dtype) for k, v in sums0.items()}

    def body(acc, mb):
        s, aux = bk_clipped_sum(apply_fn, params, mb, policy)
        acc = {k: acc[k] + s[k] for k in acc}
        return acc, (aux["loss"], aux["per_sample_norms"])

    sums, (losses, norms) = jax.lax.scan(body, zeros, mb_batch)
    res = resolve_policy(policy, flatten(params))
    flat = finalize_noise(policy, res, sums, rng, float(B), step)
    aux = {"loss": jnp.mean(losses),
           "per_sample_norms": norms.reshape(-1)}
    return unflatten(flat), aux
