"""Gradient accumulation with DP semantics (paper footnote 2): the LOGICAL
batch determines accuracy and privacy accounting; the PHYSICAL (micro) batch
only determines memory. Per-sample clipping happens inside each microbatch;
the clipped sums accumulate across microbatches in a lax.scan; noise is added
ONCE per logical batch via the policy's mechanism (per clip unit:
sigma * sigma_scale_u * composed sensitivity; tree-aggregation increments
when the policy runs DP-FTRL noise — ``step`` threads through for that).
Accepts a DPConfig or a PrivacyPolicy.

``accumulated_clipped_sum`` exposes phases 1-3 alone (the pre-noise sums) so
the mesh-native train step can fuse phase 4 directly into the optimizer's
per-leaf update (``Optimizer.update_leaves``) — no second full-size gradient
tree is ever live. ``mesh`` lowers the BK pipeline batch-sharded
(core.bk.bk_clipped_sum) and keeps the microbatch scan's carries sharded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bk import BK_MODES, batch_shard, bk_clipped_sum
from repro.core.policy import as_policy, finalize_noise, resolve_policy
from repro.utils.tree import flatten, unflatten


def accumulated_baseline_grad(apply_fn, params, batch, rng, cfg,
                              microbatch: int, step=None, mesh=None,
                              pspecs=None):
    """Microbatched accumulation for the non-BK modes (nonprivate /
    ghostclip / opacus / ...): per-microbatch grads are re-scaled to sums,
    accumulated under lax.scan, then noised once (DP modes).
    ``mesh``/``pspecs`` keep the once-per-logical-batch noise shard-local."""
    import dataclasses

    from repro.core.engine import make_grad_fn

    policy = as_policy(cfg)
    B = jax.tree_util.tree_leaves(batch)[0].shape[0]
    mb_policy = (policy if policy.mode == "nonprivate"
                 else dataclasses.replace(policy, sigma=0.0))
    grad_fn = make_grad_fn(apply_fn, mb_policy)
    if microbatch <= 0 or microbatch >= B:
        return make_grad_fn(apply_fn, policy, mesh=mesh,
                            pspecs=pspecs)(params, batch, rng, step)
    assert B % microbatch == 0, (B, microbatch)
    M = B // microbatch
    mb_batch = jax.tree_util.tree_map(
        lambda x: x.reshape((M, microbatch) + x.shape[1:]), batch)
    g0 = jax.eval_shape(
        lambda p, b: grad_fn(p, b, rng)[0], params,
        jax.tree_util.tree_map(lambda x: jax.ShapeDtypeStruct(x.shape[1:],
                                                              x.dtype),
                               mb_batch))
    zeros = jax.tree_util.tree_map(lambda v: jnp.zeros(v.shape, v.dtype), g0)

    def body(acc, mb):
        g, aux = grad_fn(params, mb, rng)
        acc = jax.tree_util.tree_map(
            lambda a, x: a + x.astype(a.dtype) * float(microbatch), acc, g)
        return acc, aux["loss"]

    sums, losses = jax.lax.scan(body, zeros, mb_batch)
    if policy.mode == "nonprivate":
        grads = jax.tree_util.tree_map(lambda s: s / float(B), sums)
    else:
        res = resolve_policy(policy, flatten(params))
        flat = finalize_noise(policy, res, flatten(sums), rng, float(B), step,
                              mesh=mesh, pspecs=pspecs)
        grads = unflatten(flat)
    return grads, {"loss": jnp.mean(losses)}


def _shard_microbatches(mb_batch, mesh, microbatch: int):
    """Pin the (M, microbatch, ...) reshape batch-sharded on dim 1 so the
    scan streams each device's slice (the reshape must not gather)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    shard = batch_shard(mesh, microbatch)
    if not shard:
        return mb_batch
    ba, _ = shard

    def pin(x):
        spec = P(*((None, ba) + (None,) * (x.ndim - 2)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(pin, mb_batch)


def accumulated_clipped_sum(apply_fn, params, batch, cfg, microbatch: int,
                            mesh=None, rng=None):
    """Phases 1-3 over the logical batch: per-sample clipping inside each
    microbatch, clipped sums accumulated under lax.scan (one microbatch's
    book-keeping live at a time — and under a layer-scope policy the
    streamed single-tap units book-keep NOTHING, so the scan body's live
    set is one fused norm+clip+grad launch per tap plus the accumulators).
    Returns (flat_sums, aux, B_logical) —
    phase 4 (noise + 1/B) is the caller's, via ``finalize_noise`` or the
    fused ``policy.noise_leaf_fn`` + ``Optimizer.update_leaves`` path.
    ``rng`` keys the tape residency layer's int8 stochastic rounding (only
    consumed when the policy stores a tap int8)."""
    policy = as_policy(cfg)
    assert policy.mode in BK_MODES, policy.mode
    B = jax.tree_util.tree_leaves(batch)[0].shape[0]
    if microbatch <= 0 or microbatch >= B:
        sums, aux = bk_clipped_sum(apply_fn, params, batch, policy, mesh=mesh,
                                   rng=rng)
        return sums, aux, B
    assert B % microbatch == 0, (B, microbatch)
    M = B // microbatch
    mb_batch = jax.tree_util.tree_map(
        lambda x: x.reshape((M, microbatch) + x.shape[1:]), batch)
    mb_batch = _shard_microbatches(mb_batch, mesh, microbatch)

    sums0, aux0 = jax.eval_shape(
        lambda p, b: bk_clipped_sum(apply_fn, p, b, policy), params,
        jax.tree_util.tree_map(lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                               mb_batch))
    zeros = {k: jnp.zeros(v.shape, v.dtype) for k, v in sums0.items()}
    # per-microbatch rounding keys: reusing ONE key would correlate the
    # int8 stochastic-rounding draws across microbatches, so the
    # accumulated sum's quantization error would stop averaging out
    rng0 = rng if rng is not None else jax.random.PRNGKey(0)

    def body(acc, xs):
        i, mb = xs
        s, aux = bk_clipped_sum(apply_fn, params, mb, policy, mesh=mesh,
                                rng=jax.random.fold_in(rng0, i))
        acc = {k: acc[k] + s[k] for k in acc}
        return acc, (aux["loss"], aux["per_sample_norms"])

    sums, (losses, norms) = jax.lax.scan(body, zeros,
                                         (jnp.arange(M), mb_batch))
    aux = {"loss": jnp.mean(losses),
           "per_sample_norms": norms.reshape(-1)}
    return sums, aux, B


def accumulated_private_grad(apply_fn, params, batch, rng, cfg,
                             microbatch: int, step=None, mesh=None,
                             pspecs=None):
    """batch leaves (B_logical, ...); microbatch must divide B_logical.
    Returns (grads, aux) identical in distribution to the full-batch BK call.
    ``mesh``/``pspecs`` lower BK batch-sharded with shard-local noise."""
    policy = as_policy(cfg)
    if policy.mode not in BK_MODES:
        return accumulated_baseline_grad(apply_fn, params, batch, rng, policy,
                                         microbatch, step, mesh=mesh,
                                         pspecs=pspecs)
    B = jax.tree_util.tree_leaves(batch)[0].shape[0]
    if microbatch <= 0 or microbatch >= B:
        from repro.core.bk import bk_private_grad
        return bk_private_grad(apply_fn, params, batch, rng, policy, step,
                               mesh=mesh, pspecs=pspecs)
    sums, aux, _ = accumulated_clipped_sum(apply_fn, params, batch, policy,
                                           microbatch, mesh=mesh, rng=rng)
    res = resolve_policy(policy, flatten(params))
    flat = finalize_noise(policy, res, sums, rng, float(B), step, mesh=mesh,
                          pspecs=pspecs)
    return unflatten(flat), aux
