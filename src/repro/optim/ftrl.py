"""Momentum DP-FTRL (Kairouz et al. 2021, "Practical and Private (Deep)
Learning without Sampling or Shuffling") in gradient-prefix +
tree-noise-prefix form.

FTRL is the tree-aggregation mechanism's native consumer: the iterate is a
function of the NOISY GRADIENT PREFIX SUM, not of per-step gradients —

    S_t     = sum_{s<=t} (g_s + [N(s) - N(s-1)])   # = G_t + N(t)
    m_t     = beta * m_{t-1} + S_t                 # momentum over prefixes
    theta_t = theta_0 - lr_t * m_t

With the 'tree' noise mechanism each grad already carries the per-step
increment N(t) - N(t-1), so the running sum the optimizer keeps is exactly
G_t + N(t): cumulative noise variance grows like popcount(t) <= log2(t)+1
node draws instead of t independent draws.

Epoch restarts (``restart_every=E``): at step t with t % E == 0 (t > 0,
BEFORE consuming that step's gradient) the optimizer rebases —
theta_0 <- theta_{t-1}, S <- 0, m <- 0 — matching the reference
FTRLOptimizer.restart(). Pair it with
``PrivacyPolicy.noise_restart_every=E`` so the tree mechanism rebuilds its
tree at the same boundary (and, with ``noise_completion=True``, the state
being rebased on carries the completed tree's single-root-node variance —
the honest-restart correction).

State is three param-shaped f32 trees (sum / momentum / theta0); sharding
follows params under pjit like every other optimizer here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer, _materialized, _tmap

F32 = jnp.float32


def epoch_of(step: int, restart_every: int) -> int:
    """Which restart epoch (tree index) absolute ``step`` falls in.

    The FTRL/tree 'position' needs no dedicated checkpoint field: the
    optimizer state carries (anchor, prefix, momentum) and the epoch — both
    the anchor's rebase boundary and the noise tree's index — is this pure
    function of the ABSOLUTE step, which the TrainState persists. Resuming
    mid-epoch is exact because ``_restart_keep`` and the tree mechanism
    both key off that same absolute step."""
    return int(step) // restart_every if restart_every > 0 else 0


def ftrl(lr_fn, momentum: float = 0.0, restart_every: int = 0,
         weight_decay: float = 0.0) -> Optimizer:
    """Momentum DP-FTRL. ``weight_decay`` must be 0: FTRL's iterate is an
    anchor-plus-prefix form with no decoupled-decay analogue; raising beats
    silently ignoring the argument."""
    if weight_decay:
        raise ValueError("DP-FTRL has no decoupled weight decay "
                         f"(got weight_decay={weight_decay}); use 0")
    if restart_every < 0:
        raise ValueError(f"restart_every must be >= 0, got {restart_every}")

    def init(params):
        z = lambda p: jnp.zeros_like(p, F32)
        # jnp.array (not astype): astype is a no-op ALIAS for f32 params,
        # and a state that shares buffers with the params breaks the train
        # step's whole-TrainState donation (same buffer donated twice)
        return {"sum": _tmap(z, params), "m": _tmap(z, params),
                "theta0": _tmap(lambda p: jnp.array(p, dtype=F32), params)}

    def _restart_keep(step):
        if restart_every:
            # rebase BEFORE consuming this step's gradient (the previous
            # step's iterate becomes the new anchor); works under jit with a
            # traced step
            restart = jnp.logical_and(jnp.asarray(step) > 0,
                                      jnp.asarray(step) % restart_every == 0)
        else:
            restart = jnp.asarray(False)
        return restart, jnp.where(restart, 0.0, 1.0).astype(F32)

    def update_leaves(grad_for, state, params, step):
        from repro.utils.tree import flatten, unflatten
        lr = lr_fn(step)
        restart, keep = _restart_keep(step)
        fp = flatten(params)
        fs, fm, ft = (flatten(state["sum"]), flatten(state["m"]),
                      flatten(state["theta0"]))
        new_s, new_m, new_t, new_p = {}, {}, {}, {}
        for path, p in fp.items():
            t0 = jnp.where(restart, p.astype(F32), ft[path])
            s_ = keep * fs[path] + grad_for(path, p).astype(F32)
            m_ = momentum * keep * fm[path] + s_
            new_t[path], new_s[path], new_m[path] = t0, s_, m_
            new_p[path] = (t0 - lr * m_).astype(p.dtype)
        return unflatten(new_p), {"sum": unflatten(new_s),
                                  "m": unflatten(new_m),
                                  "theta0": unflatten(new_t)}

    return Optimizer(init, _materialized(update_leaves), update_leaves)
