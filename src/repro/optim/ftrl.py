"""Momentum DP-FTRL (Kairouz et al. 2021, "Practical and Private (Deep)
Learning without Sampling or Shuffling") in gradient-prefix +
tree-noise-prefix form.

FTRL is the tree-aggregation mechanism's native consumer: the iterate is a
function of the NOISY GRADIENT PREFIX SUM, not of per-step gradients —

    S_t     = sum_{s<=t} (g_s + [N(s) - N(s-1)])   # = G_t + N(t)
    m_t     = beta * m_{t-1} + S_t                 # momentum over prefixes
    theta_t = theta_0 - lr_t * m_t

With the 'tree' noise mechanism each grad already carries the per-step
increment N(t) - N(t-1), so the running sum the optimizer keeps is exactly
G_t + N(t): cumulative noise variance grows like popcount(t) <= log2(t)+1
node draws instead of t independent draws.

Epoch restarts (``restart_every=E``): at step t with t % E == 0 (t > 0,
BEFORE consuming that step's gradient) the optimizer rebases —
theta_0 <- theta_{t-1}, S <- 0, m <- 0 — matching the reference
FTRLOptimizer.restart(). Pair it with
``PrivacyPolicy.noise_restart_every=E`` so the tree mechanism rebuilds its
tree at the same boundary (and, with ``noise_completion=True``, the state
being rebased on carries the completed tree's single-root-node variance —
the honest-restart correction).

State is three param-shaped f32 trees (sum / momentum / theta0); sharding
follows params under pjit like every other optimizer here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer, _tmap

F32 = jnp.float32


def ftrl(lr_fn, momentum: float = 0.0, restart_every: int = 0,
         weight_decay: float = 0.0) -> Optimizer:
    """Momentum DP-FTRL. ``weight_decay`` must be 0: FTRL's iterate is an
    anchor-plus-prefix form with no decoupled-decay analogue; raising beats
    silently ignoring the argument."""
    if weight_decay:
        raise ValueError("DP-FTRL has no decoupled weight decay "
                         f"(got weight_decay={weight_decay}); use 0")
    if restart_every < 0:
        raise ValueError(f"restart_every must be >= 0, got {restart_every}")

    def init(params):
        z = lambda p: jnp.zeros_like(p, F32)
        return {"sum": _tmap(z, params), "m": _tmap(z, params),
                "theta0": _tmap(lambda p: p.astype(F32), params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)
        if restart_every:
            # rebase BEFORE consuming this step's gradient (the previous
            # step's iterate becomes the new anchor); works under jit with a
            # traced step
            restart = jnp.logical_and(jnp.asarray(step) > 0,
                                      jnp.asarray(step) % restart_every == 0)
        else:
            restart = jnp.asarray(False)
        keep = jnp.where(restart, 0.0, 1.0).astype(F32)
        theta0 = _tmap(lambda t0, p: jnp.where(restart, p.astype(F32), t0),
                       state["theta0"], params)
        s = _tmap(lambda s_, g: keep * s_ + g.astype(F32),
                  state["sum"], grads)
        m = _tmap(lambda m_, s_: momentum * keep * m_ + s_, state["m"], s)
        new_p = _tmap(lambda t0, m_, p: (t0 - lr * m_).astype(p.dtype),
                      theta0, m, params)
        return new_p, {"sum": s, "m": m, "theta0": theta0}

    return Optimizer(init, update)
