"""Flash-attention forward Pallas kernel (TPU): blockwise online softmax,
GQA-aware via BlockSpec index mapping (no KV head replication in HBM).

Layouts: q (B,H,T,h), k/v (B,K,S,h), out (B,H,T,h); grid (B,H,nQ,nKV) with
the KV dim innermost/sequential; running (m, l, acc) live in VMEM scratch.
Causal blocks strictly above the diagonal are skipped with pl.when.
Serving prefill path; training uses XLA attention + remat.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            causal, scale, bq, bk, nkv):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True if not causal else (kj * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(F32) * scale          # (bq, h)
        k = k_ref[0, 0].astype(F32)                  # (bk, h)
        v = v_ref[0, 0].astype(F32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32)  # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot(p, v, preferred_element_type=F32))
        m_ref[...] = m_new

    @pl.when(kj == nkv - 1)
    def _write():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q (B,T,H,h), k/v (B,S,K,h) with H = K*G -> (B,T,H,h)."""
    B, T, H, h = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    qt = jnp.swapaxes(q, 1, 2)               # (B,H,T,h)
    kt = jnp.swapaxes(k, 1, 2)               # (B,K,S,h)
    vt = jnp.swapaxes(v, 1, 2)
    bq, bk = min(block_q, T), min(block_k, S)
    assert T % bq == 0 and S % bk == 0, "pad T/S to block multiples"
    nq, nkv = T // bq, S // bk

    kern = functools.partial(_kernel, causal=causal, scale=1.0 / h ** 0.5,
                             bq=bq, bk=bk, nkv=nkv)
    out = pl.pallas_call(
        kern,
        grid=(B, H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, h), lambda b, hh, i, j: (b, hh, i, 0)),
            pl.BlockSpec((1, 1, bk, h), lambda b, hh, i, j: (b, hh // G, j, 0)),
            pl.BlockSpec((1, 1, bk, h), lambda b, hh, i, j: (b, hh // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, h), lambda b, hh, i, j: (b, hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, h), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, h), F32), pltpu.VMEM((bq,), F32),
                        pltpu.VMEM((bq,), F32)],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.swapaxes(out, 1, 2)
