"""Fused MoE ghost/direct-norm and clipped-grad Pallas kernels (TPU) over the
per-(sample, expert) capacity layout (models.moe):

    a (B,E,C,d)  mask (B,E,C)  ds (B,E,C,p)     stacked: leading L axis

The slot-validity mask is applied **in-register** to the cotangent tile, so
neither the masked copies nor the (B,E,C,C) Grams / (B,E,d,p) per-sample
expert grads ever exist in HBM (the pure-jnp path materializes all three).
Beyond-paper extension — the paper never treats MoE; this carries its
module 3/4/5 fusion to the expert-parallel layout.

  moe_ghost_norm    n_b = sum_{l,e} <am am^T, dm dm^T>_F     grid (B, L, E)
  moe_direct_norm   n_b = sum_{l,e} ||a_e^T dm_e||_F^2       grid (B,L,E,nd,np)
  moe_clipped_grad  G_le = sum_b C_b a_be^T dm_be            grid (L,E,nd,np,B)

Capacity C is small by construction (T * capacity_factor * top_k / E), so the
(C,*) blocks are kept whole; only d/p are tiled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _moe5(a, mask, ds):
    if a.ndim == 4:
        return a[None], mask[None], ds[None], True
    if a.ndim == 5:
        return a, mask, ds, False
    raise ValueError(f"moe record must be 4D or 5D, got {a.shape}")


# ------------------------------------------------------------- ghost norm
def _ghost_kernel(a_ref, m_ref, g_ref, out_ref):
    l = pl.program_id(1)
    e = pl.program_id(2)

    @pl.when((l == 0) & (e == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    m = m_ref[0, 0, 0].astype(F32)                    # (C,)
    am = a_ref[0, 0, 0].astype(F32) * m[:, None]      # (C, d)
    dm = g_ref[0, 0, 0].astype(F32) * m[:, None]      # (C, p)
    gram_a = jax.lax.dot_general(am, am, (((1,), (1,)), ((), ())),
                                 preferred_element_type=F32)
    gram_g = jax.lax.dot_general(dm, dm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=F32)
    out_ref[0] += jnp.sum(gram_a * gram_g)


@functools.partial(jax.jit, static_argnames=("interpret",))
def moe_ghost_norm(a, mask, ds, interpret: bool = False):
    """a (B,E,C,d)/(L,B,E,C,d), mask (...,E,C), ds (...,E,C,p) -> (B,) f32."""
    a, mask, ds, _ = _moe5(a, mask, ds)
    L, B, E, C, d = a.shape
    p = ds.shape[-1]
    out = pl.pallas_call(
        _ghost_kernel,
        grid=(B, L, E),
        in_specs=[
            pl.BlockSpec((1, 1, 1, C, d), lambda b, l, e: (l, b, e, 0, 0)),
            pl.BlockSpec((1, 1, 1, C), lambda b, l, e: (l, b, e, 0)),
            pl.BlockSpec((1, 1, 1, C, p), lambda b, l, e: (l, b, e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b, l, e: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), F32),
        interpret=interpret,
    )(a, mask, ds)
    return out


# ------------------------------------------------------------ direct norm
def _direct_kernel(a_ref, m_ref, g_ref, out_ref):
    l = pl.program_id(1)
    e = pl.program_id(2)
    i = pl.program_id(3)
    j = pl.program_id(4)

    @pl.when((l == 0) & (e == 0) & (i == 0) & (j == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    m = m_ref[0, 0, 0].astype(F32)                    # (C,)
    a = a_ref[0, 0, 0].astype(F32)                    # (C, bd)
    dm = g_ref[0, 0, 0].astype(F32) * m[:, None]      # (C, bp)
    tile = jax.lax.dot_general(a, dm, (((0,), (0,)), ((), ())),
                               preferred_element_type=F32)
    out_ref[0] += jnp.sum(tile * tile)


@functools.partial(jax.jit, static_argnames=("block_d", "block_p", "interpret"))
def moe_direct_norm(a, mask, ds, block_d: int = 256, block_p: int = 256,
                    interpret: bool = False):
    """Per-(sample, expert) instantiated-grad norm, summed over experts."""
    a, mask, ds, _ = _moe5(a, mask, ds)
    L, B, E, C, d = a.shape
    p = ds.shape[-1]
    bd, bp = min(block_d, d), min(block_p, p)
    if d % bd:
        a = jnp.pad(a, ((0, 0),) * 4 + ((0, bd - d % bd),))
        d = a.shape[-1]
    if p % bp:
        ds = jnp.pad(ds, ((0, 0),) * 4 + ((0, bp - p % bp),))
        p = ds.shape[-1]
    out = pl.pallas_call(
        _direct_kernel,
        grid=(B, L, E, d // bd, p // bp),
        in_specs=[
            pl.BlockSpec((1, 1, 1, C, bd),
                         lambda b, l, e, i, j: (l, b, e, 0, i)),
            pl.BlockSpec((1, 1, 1, C), lambda b, l, e, i, j: (l, b, e, 0)),
            pl.BlockSpec((1, 1, 1, C, bp),
                         lambda b, l, e, i, j: (l, b, e, 0, j)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b, l, e, i, j: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), F32),
        interpret=interpret,
    )(a, mask, ds)
    return out


# ----------------------------------------------------------- clipped grad
def _grad_kernel(a_ref, m_ref, g_ref, c_ref, out_ref):
    b = pl.program_id(4)

    @pl.when(b == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    m = m_ref[0, 0, 0].astype(F32)                    # (C,)
    a = a_ref[0, 0, 0].astype(F32)                    # (C, bd)
    dm = g_ref[0, 0, 0].astype(F32) * m[:, None]      # (C, bp)
    c = c_ref[0].astype(F32)
    tile = jax.lax.dot_general(a * c, dm, (((0,), (0,)), ((), ())),
                               preferred_element_type=F32)
    out_ref[0, 0] += tile


@functools.partial(jax.jit, static_argnames=("block_d", "block_p", "interpret"))
def moe_clipped_grad(a, mask, C, ds, block_d: int = 256, block_p: int = 256,
                     interpret: bool = False):
    """-> (E,d,p) f32, or (L,E,d,p) for stacked records. One launch."""
    a, mask, ds, squeeze = _moe5(a, mask, ds)
    L, B, E, Cap, d = a.shape
    p = ds.shape[-1]
    bd, bp = min(block_d, d), min(block_p, p)
    pd_, pp_ = (bd - d % bd) % bd, (bp - p % bp) % bp
    if pd_:
        a = jnp.pad(a, ((0, 0),) * 4 + ((0, pd_),))
    if pp_:
        ds = jnp.pad(ds, ((0, 0),) * 4 + ((0, pp_),))
    D, P = a.shape[-1], ds.shape[-1]
    out = pl.pallas_call(
        _grad_kernel,
        grid=(L, E, D // bd, P // bp, B),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Cap, bd),
                         lambda l, e, i, j, b: (l, b, e, 0, i)),
            pl.BlockSpec((1, 1, 1, Cap), lambda l, e, i, j, b: (l, b, e, 0)),
            pl.BlockSpec((1, 1, 1, Cap, bp),
                         lambda l, e, i, j, b: (l, b, e, 0, j)),
            pl.BlockSpec((1,), lambda l, e, i, j, b: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, bd, bp),
                               lambda l, e, i, j, b: (l, e, i, j)),
        out_shape=jax.ShapeDtypeStruct((L, E, D, P), F32),
        interpret=interpret,
    )(a, mask, ds, C)
    out = out[:, :, :d, :p]
    return out[0] if squeeze else out
