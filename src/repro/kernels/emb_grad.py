"""Fused clipped embedding-gradient Pallas kernel (TPU): BK line 9 for an
embedding lookup,

    G_l[v] = sum_b C_b sum_t 1[id_lbt == v] ds_lbt        -> (L, V, d)

i.e. a clip-weighted scatter-add of the cotangents into vocab rows. The jnp
path materializes the (B,T,d) intermediate C*ds in HBM and then scatter-adds
it; here the vocab axis is tiled and each (bv, d) output tile is accumulated
in VMEM across samples: the tile membership one-hot 1[id == v0+arange(bv)]
is built in-register from the id tile and contracted against the cotangents
on the MXU with the clip factor fused in — no weighted copy, no HBM one-hot,
and each output row is written exactly once.

Grid (L, V/bv, B), B innermost. Cost note: the cotangents are re-read once
per vocab tile, so bv should be as large as VMEM allows (dispatch picks it);
the scatter alternative (sequential dynamic-indexed row updates) cannot keep
a V*d output resident in VMEM for real vocabularies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _kernel(ids_ref, g_ref, c_ref, out_ref):
    b = pl.program_id(2)

    @pl.when(b == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bv = out_ref.shape[1]
    v0 = pl.program_id(1) * bv
    ids = ids_ref[0, 0]                       # (T,) int
    g = g_ref[0, 0].astype(F32)               # (T, d)
    c = c_ref[0].astype(F32)
    vrange = v0 + jax.lax.broadcasted_iota(jnp.int32, (1, bv), 1)
    onehot = (ids[:, None] == vrange).astype(F32)            # (T, bv)
    tile = jax.lax.dot_general(onehot, g, (((0,), (0,)), ((), ())),
                               preferred_element_type=F32)   # (bv, d)
    out_ref[0] += c * tile


@functools.partial(jax.jit, static_argnames=("vocab", "block_v", "interpret"))
def emb_clipped_grad(ids, C, ds, vocab: int, block_v: int = 512,
                     interpret: bool = False):
    """ids (L,B,T) or (B,T) int, C (B,), ds (L,B,T,d) or (B,T,d)
    -> (L,vocab,d) or (vocab,d) f32."""
    squeeze = ids.ndim == 2
    if squeeze:
        ids, ds = ids[None], ds[None]
    L, B, T = ids.shape
    d = ds.shape[-1]
    bv = min(block_v, vocab)
    nv = pl.cdiv(vocab, bv)
    V = nv * bv  # padded vocab rows stay zero: no id can match them

    out = pl.pallas_call(
        _kernel,
        grid=(L, nv, B),
        in_specs=[
            pl.BlockSpec((1, 1, T), lambda l, v, b: (l, b, 0)),
            pl.BlockSpec((1, 1, T, d), lambda l, v, b: (l, b, 0, 0)),
            pl.BlockSpec((1,), lambda l, v, b: (b,)),
        ],
        out_specs=pl.BlockSpec((1, bv, d), lambda l, v, b: (l, v, 0)),
        out_shape=jax.ShapeDtypeStruct((L, V, d), F32),
        interpret=interpret,
    )(ids, ds, C)
    out = out[:, :vocab]
    return out[0] if squeeze else out
