"""Fused embedding ghost-norm Pallas kernel (TPU): per-sample squared
gradient norms of an embedding lookup (Li et al. 2021),

    n_b = sum_l sum_{t,t'} 1[id_lbt == id_lbt'] (ds_lbt . ds_lbt')

with the (T,T) indicator formed **in-register** from two id tiles and the
(T,T) cotangent Gram formed on the MXU — neither the (B,T,T) indicator nor
the Gram ever exists in HBM (the pure-jnp path materializes both).

Grid (B, L, tri(nt)): same packed-triangular tile enumeration as
kernels.ghost_norm (scalar-prefetched (i,j) table; off-diagonal tiles count
twice by symmetry), with stacked (L,B,T) records one kernel launch via the
L grid axis. VMEM per step: 2*bt ids + 2*bt*d cotangents + bt^2 floats.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ghost_norm import tri_table

F32 = jnp.float32


def _kernel(ij_ref, ii_ref, jj_ref, gi_ref, gj_ref, out_ref):
    l = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((l == 0) & (k == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ii = ii_ref[0, 0]                        # (bt,) int ids
    jj = jj_ref[0, 0]
    gi = gi_ref[0, 0].astype(F32)            # (bt, d)
    gj = gj_ref[0, 0].astype(F32)
    eq = (ii[:, None] == jj[None, :]).astype(F32)          # (bt, bt) in-register
    gram_g = jax.lax.dot_general(gi, gj, (((1,), (1,)), ((), ())),
                                 preferred_element_type=F32)
    contrib = jnp.sum(eq * gram_g)
    scale = jnp.where(ij_ref[k, 0] == ij_ref[k, 1], 1.0, 2.0)
    out_ref[0] += scale * contrib


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def emb_ghost_norm(ids, ds, block_t: int = 128, interpret: bool = False):
    """ids (L,B,T) or (B,T) int, ds (L,B,T,d) or (B,T,d) -> (B,) f32."""
    if ids.ndim == 2:
        ids, ds = ids[None], ds[None]
    L, B, T = ids.shape
    d = ds.shape[-1]
    bt = min(block_t, T)
    if T % bt:
        pad = bt - T % bt
        # pad ids with -1: padded slots only match other padding, whose
        # cotangents are zero-padded, so they contribute exactly 0
        ids = jnp.pad(ids, ((0, 0), (0, 0), (0, pad)), constant_values=-1)
        ds = jnp.pad(ds, ((0, 0), (0, 0), (0, pad), (0, 0)))
        T = ids.shape[2]
    nt = T // bt
    ij = jnp.asarray(tri_table(nt))
    ntri = ij.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, L, ntri),
        in_specs=[
            pl.BlockSpec((1, 1, bt), lambda b, l, k, ij: (l, b, ij[k, 0])),
            pl.BlockSpec((1, 1, bt), lambda b, l, k, ij: (l, b, ij[k, 1])),
            pl.BlockSpec((1, 1, bt, d), lambda b, l, k, ij: (l, b, ij[k, 0], 0)),
            pl.BlockSpec((1, 1, bt, d), lambda b, l, k, ij: (l, b, ij[k, 1], 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b, l, k, ij: (b,)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B,), F32),
        interpret=interpret,
    )(ij, ids, ids, ds, ds)
