"""Chunked RWKV6 recurrence Pallas kernel (TPU).

Within a chunk of c tokens the recurrence is re-expressed as matmuls
(MXU-friendly) instead of c sequential steps:

    P_t   = prod_{s<=t} w_s                      (per-channel, cumprod)
    out_t = (r_t*P_{t-1}) S_in
            + sum_{s<t} <r_t*P_{t-1}, k_s/P_s> v_s      (strict-lower mask)
            + <r_t*u, k_t> v_t                          (diagonal bonus)
    S_out = diag(P_c) S_in + (k/P * P_c)^T V

The cumulative log-decay is computed with a lower-triangular ones matmul
(MXU) rather than a serial scan. State S (h,h) persists in VMEM scratch
across the sequential chunk grid dimension. Chunk size is kept small (16-32)
so the P ratios stay in f32 range (decays are clamped).

Layouts: r,k,v,w (B,H,T,h) [wrapper transposes from (B,T,H,h)], u (H,h).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
MIN_LOGW = -20.0  # per-token log-decay clamp; exp(-20*c) stays > f32 tiny for c<=4... chunk guard below


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *, c, h):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, 0].astype(F32)               # (c, h)
    k = k_ref[0, 0].astype(F32)
    v = v_ref[0, 0].astype(F32)
    w = w_ref[0, 0].astype(F32)
    u = u_ref[0].astype(F32)                  # (h,)
    S = s_ref[...]                            # (h, h)

    logw = jnp.maximum(jnp.log(jnp.maximum(w, 1e-30)), MIN_LOGW)
    tril_inc = (jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
                >= jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)).astype(F32)
    cum = jax.lax.dot(tril_inc, logw, preferred_element_type=F32)  # (c,h) inclusive
    P = jnp.exp(cum)                          # P_t
    P_prev = jnp.exp(cum - logw)              # P_{t-1}

    r_t = r * P_prev                          # (c,h)
    k_t = k / jnp.maximum(P, 1e-30)           # (c,h)

    A = jax.lax.dot_general(r_t, k_t, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32)      # (c,c)
    strict = (jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
              > jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)).astype(F32)
    diag_bonus = jnp.sum(r * u[None, :] * k, axis=1)         # (c,)
    eye = (jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
           == jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)).astype(F32)
    Af = A * strict + eye * diag_bonus[:, None]
    out = (jax.lax.dot(Af, v, preferred_element_type=F32)
           + jax.lax.dot(r_t, S, preferred_element_type=F32))
    o_ref[0, 0] = out.astype(o_ref.dtype)

    Pc = P[-1]                                # (h,)
    k_scaled = k_t * Pc[None, :]
    s_ref[...] = (Pc[:, None] * S
                  + jax.lax.dot_general(k_scaled, v, (((0,), (0,)), ((), ())),
                                        preferred_element_type=F32))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, chunk: int = 16, interpret: bool = False):
    """r,k,v,w (B,T,H,h); u (H,h) -> (B,T,H,h) f32 output."""
    B, T, H, h = r.shape
    c = min(chunk, T)
    pad = (c - T % c) % c
    if pad:
        zp = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    Tp = r.shape[1]
    tr = lambda x: jnp.swapaxes(x, 1, 2)      # (B,H,T,h)

    kern = functools.partial(_kernel, c=c, h=h)
    out = pl.pallas_call(
        kern,
        grid=(B, H, Tp // c),
        in_specs=[
            pl.BlockSpec((1, 1, c, h), lambda b, hh, t: (b, hh, t, 0)),
            pl.BlockSpec((1, 1, c, h), lambda b, hh, t: (b, hh, t, 0)),
            pl.BlockSpec((1, 1, c, h), lambda b, hh, t: (b, hh, t, 0)),
            pl.BlockSpec((1, 1, c, h), lambda b, hh, t: (b, hh, t, 0)),
            pl.BlockSpec((1, h), lambda b, hh, t: (hh, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, c, h), lambda b, hh, t: (b, hh, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Tp, h), F32),
        scratch_shapes=[pltpu.VMEM((h, h), F32)],
        interpret=interpret,
    )(tr(r), tr(k), tr(v), tr(w), u)
    out = jnp.swapaxes(out, 1, 2)
    return out[:, :T]
