"""Kernel dispatch + autotune: the policy layer over the fused Pallas kernels.

Extends the paper's layerwise ghost-vs-direct rule (He et al. 2022;
``ghost.prefer_ghost``) one level down — from *algorithm* choice to *kernel*
choice — per tapped op:

  1. method   ghost vs direct, from the 2T^2 <-> pd space rule (mode 'bk'
              forces ghost, matching the engine's mode semantics);
  2. impl     fused Pallas kernel vs pure-jnp einsum: the kernel's win is
              never materializing the Gram / per-sample-grad intermediate in
              HBM, so records whose intermediate is tiny (fits in registers
              anyway, launch overhead dominates) stay on the jnp path;
  3. blocks   tile sizes chosen so one grid step's operands fit the VMEM
              working-set budget, snapped to hardware-friendly multiples.

Plans are cached per (kind, method, shape, backend). ``autotune`` replaces
the analytic block choice with measured timings on synthetic data (run it
OUTSIDE jit — e.g. from benchmarks/kernel_bench.py or engine warmup — the
measured blocks then win the cache for identical shapes). Environment knobs:

  REPRO_KERNELS=0        force the jnp path everywhere (kill switch)
  REPRO_KERNELS=1        plan the kernel impl even for tiny records (the
                         engine still honors DPConfig.use_kernels=False)
  REPRO_KERNEL_MIN=<n>   impl threshold, in intermediate elements (def. 256)
"""
from __future__ import annotations

import functools
import os
import time
from dataclasses import dataclass

import jax

# f32 bytes one grid step may hold in VMEM (half of ~16 MB/core, leaving the
# other half to Mosaic's double buffering of the next step's blocks)
VMEM_BUDGET = 6 * 2 ** 20

# below this many elements for the avoided intermediate, a fused kernel
# cannot pay for its launch: stay on the (fully XLA-fusable) jnp path
KERNEL_MIN_INTERMEDIATE = 256

_BT_CANDIDATES = (1024, 512, 256, 128, 64, 32, 16, 8)
_BDP_CANDIDATES = (1024, 512, 256, 128, 64, 32, 16, 8)
_BV_CANDIDATES = (4096, 2048, 1024, 512, 256, 128)

_plan_cache: dict = {}


def backend() -> str:
    return jax.default_backend()


def _rup(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclass(frozen=True)
class Plan:
    impl: str        # 'kernel' | 'jnp'
    method: str      # 'ghost' | 'direct' | 'scatter' (emb grad)
    blocks: tuple    # ((name, value), ...) kwargs for the kernels.ops wrapper

    def kwargs(self) -> dict:
        return dict(self.blocks)


# ------------------------------------------------------------- block model
def block_t_ghost(T: int, d: int, p: int) -> int:
    """Tile of the packed-triangular ghost-norm grid: 2bt(d+p) operands plus
    3bt^2 live Gram registers per step."""
    cap = _rup(min(T, _BT_CANDIDATES[0]), 8)
    for bt in _BT_CANDIDATES:
        if bt <= cap and 4 * (2 * bt * (d + p) + 3 * bt * bt) <= VMEM_BUDGET:
            return bt
    return 8


def block_dp(T: int, d: int, p: int) -> tuple:
    """(bd, bp) for the instantiation-style grids: T(bd+bp) operands plus a
    bd*bp tile per step."""
    capd = _rup(min(d, _BDP_CANDIDATES[0]), 8)
    capp = _rup(min(p, _BDP_CANDIDATES[0]), 8)
    for b in _BDP_CANDIDATES:
        bd, bp = min(b, capd), min(b, capp)
        if 4 * (T * (bd + bp) + bd * bp) <= VMEM_BUDGET:
            return bd, bp
    return 8, 8


def block_v(T: int, d: int, vocab: int) -> int:
    """Vocab tile of the clipped-embedding-grad grid: T*bv one-hot + bv*d
    output tile + T*d cotangents per step."""
    cap = _rup(min(vocab, _BV_CANDIDATES[0]), 128)
    for bv in _BV_CANDIDATES:
        if bv <= cap and 4 * (T * bv + bv * d + T * d) <= VMEM_BUDGET:
            return bv
    return 128


# -------------------------------------------------------------- impl model
def _env_state() -> tuple:
    return (os.environ.get("REPRO_KERNELS", ""),
            os.environ.get("REPRO_KERNEL_MIN", ""))


def _impl(intermediate_elems: int) -> str:
    force, min_ = _env_state()
    if force == "0":
        return "jnp"
    if force == "1":
        return "kernel"
    thresh = int(min_) if min_ else KERNEL_MIN_INTERMEDIATE
    return "kernel" if intermediate_elems >= thresh else "jnp"


def _cached(key, mk_plan):
    # env knobs are part of the key so flipping REPRO_KERNELS mid-process
    # invalidates previously planned shapes rather than being ignored
    key = key + _env_state()
    plan = _plan_cache.get(key)
    if plan is None:
        plan = mk_plan()
        _plan_cache[key] = plan
    return plan


# ------------------------------------------------------------------- plans
def norm_plan(kind: str, act_shape, ds_shape, mode: str,
              method: str = "") -> Plan:
    """Per-tap plan for the phase-2 per-sample squared norm.

    ``method`` ('ghost' | 'direct') is the per-ParamGroup override from the
    privacy policy: when set it wins over both the mode-'bk' forced-ghost
    rule and the layerwise 2T^2-vs-pd heuristic."""
    key = ("norm", kind, tuple(act_shape), tuple(ds_shape), mode, method,
           backend())

    def mk():
        if kind == "mm":
            a = act_shape if len(act_shape) == 4 else (1,) + tuple(act_shape)
            L, B, T, d = a
            p = ds_shape[-1]
            from repro.core.ghost import prefer_ghost
            m = method or ("ghost" if mode == "bk" or prefer_ghost(T, d, p)
                           else "direct")
            inter = L * B * (2 * T * T if m == "ghost" else d * p)
            blocks = (("block_t", block_t_ghost(T, d, p)),) \
                if m == "ghost" else \
                tuple(zip(("block_d", "block_p"), block_dp(T, d, p)))
            return Plan(_impl(inter), m, blocks)
        if kind == "emb":
            ids = act_shape if len(act_shape) == 3 else (1,) + tuple(act_shape)
            L, B, T = ids
            d = ds_shape[-1]
            # ghost is the only sane norm for embeddings: direct would
            # instantiate (B, V, d); a 'direct' group override is ignored
            return Plan(_impl(L * B * T * T), "ghost",
                        (("block_t", block_t_ghost(T, d, d)),))
        if kind == "moe":
            a = act_shape if len(act_shape) == 5 else (1,) + tuple(act_shape)
            L, B, E, C, d = a
            p = ds_shape[-1]
            from repro.core.ghost import prefer_ghost
            m = method or ("ghost" if mode == "bk" or prefer_ghost(C, d, p)
                           else "direct")
            inter = L * B * E * (2 * C * C if m == "ghost" else d * p)
            blocks = () if m == "ghost" else \
                tuple(zip(("block_d", "block_p"), block_dp(C, d, p)))
            return Plan(_impl(inter), m, blocks)
        raise ValueError(f"unknown tap kind {kind!r}")

    return _cached(key, mk)


def fused_plan(kind: str, act_shape, ds_shape, mode: str,
               method: str = "") -> Plan:
    """Per-tap plan for a STREAMED single-tap clip unit (scope='layer'):
    phases 2+3 fused at the tap — per-sample norm, clip factor and weighted
    grad in one pass over the cotangent.

    method 'fused'  ONE kernel launch (kernels.fused_clip): per grid step the
                    whole per-sample gradient g_b = a_b^T ds_b lives in VMEM,
                    the norm/clip happen in-register, and C_b * g_b folds into
                    the output accumulator — the contraction runs ONCE (the
                    mixopt trick without the HBM cache). Chosen when the
                    per-sample working set fits the VMEM budget. Not under
                    mode 'bk' (forced-ghost norms) or a 'ghost' group
                    override — those compose the ghost-norm kernel instead.
    method 'split'  compose the existing norm + weighted-grad paths back to
                    back (still streamed: nothing held between them).

    impl 'jnp' on a fused plan is the einsum form of the same single-pass
    contraction (instantiate g once, norm + weight it immediately)."""
    key = ("fused", kind, tuple(act_shape), tuple(ds_shape), mode, method,
           backend())

    def mk():
        if kind != "mm" or mode == "bk" or method == "ghost":
            return Plan("jnp", "split", ())
        a = act_shape if len(act_shape) == 4 else (1,) + tuple(act_shape)
        L, B, T, d = a
        p = ds_shape[-1]
        # per grid step (one sample): a (L,T,d) + ds (L,T,p) operands, the
        # instantiated g (L,d,p) and the (L,d,p) output accumulator
        fits = 4 * (L * T * (d + p) + 2 * L * d * p) <= VMEM_BUDGET
        if not fits:
            return Plan("jnp", "split", ())
        # the avoided intermediate is the second a^T ds contraction's reads
        # plus the held cotangent — same scale as the direct-norm grid
        return Plan(_impl(L * B * d * p), "fused", ())

    return _cached(key, mk)


def grad_plan(kind: str, act_shape, ds_shape, vocab: int = 0) -> Plan:
    """Per-tap plan for the phase-3 clip-weighted gradient (BK line 9)."""
    key = ("grad", kind, tuple(act_shape), tuple(ds_shape), vocab, backend())

    def mk():
        if kind == "mm":
            a = act_shape if len(act_shape) == 4 else (1,) + tuple(act_shape)
            L, B, T, d = a
            p = ds_shape[-1]
            # the kernel fuses diag(C): the avoided HBM intermediate is the
            # (L,B,T,p) weighted cotangent copy
            return Plan(_impl(L * B * T * p), "direct",
                        tuple(zip(("block_d", "block_p"), block_dp(T, d, p))))
        if kind == "emb":
            ids = act_shape if len(act_shape) == 3 else (1,) + tuple(act_shape)
            L, B, T = ids
            d = ds_shape[-1]
            return Plan(_impl(L * B * T * d), "scatter",
                        (("block_v", block_v(T, d, vocab)),))
        if kind == "moe":
            a = act_shape if len(act_shape) == 5 else (1,) + tuple(act_shape)
            L, B, E, C, d = a
            p = ds_shape[-1]
            return Plan(_impl(L * B * E * C * p), "direct",
                        tuple(zip(("block_d", "block_p"), block_dp(C, d, p))))
        raise ValueError(f"unknown tap kind {kind!r}")

    return _cached(key, mk)


# -------------------------------------------------------- residency planner
# The tape residency planner extends the cost model one more level: after
# method (ghost/direct) and impl (kernel/jnp), decide how each tap's
# book-kept state — the held cotangent ds plus the stored activation copy —
# RESIDES between BK phases 2 and 3: stored native, compressed (bf16/int8),
# or not at all (recompute: a second chunked backward sweep re-derives ds in
# phase 3). The analytic rule is bytes-thresholded (compression is ~free,
# recompute costs a partial backward, so small records stay native, mid-size
# records compress, and only records big enough to dominate the book-kept
# footprint pay the re-derivation FLOPs); like the block model it is
# env-tunable, and benchmarks/step_bench.py measures the real per-policy
# peak-HBM/step-time cells the way kernel_bench measures block candidates.
#
#   REPRO_TAPE=<store>            force one store decision everywhere
#   REPRO_TAPE_BF16_MIN=<bytes>   compress records held >= this (def. 64 KiB)
#   REPRO_TAPE_RECOMPUTE_MIN=<b>  re-derive records held >= this (def. 8 MiB)

TAPE_STORES = ("native", "bf16", "int8", "recompute")

TAPE_BF16_MIN = 64 * 2 ** 10
TAPE_RECOMPUTE_MIN = 8 * 2 ** 20


@dataclass(frozen=True)
class TapePlan:
    store: str            # one of TAPE_STORES
    hold_bytes: int       # bytes this tap holds live between phases 2 and 3
    recompute_flops: int  # modeled phase-3 re-derivation cost (paid only
                          # when store == 'recompute')
    itemsize: int = 4     # the cotangent's native dtype width (model dtype)


def _prod(shape) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    return n


def _tape_env() -> tuple:
    return (os.environ.get("REPRO_TAPE", ""),
            os.environ.get("REPRO_TAPE_BF16_MIN", ""),
            os.environ.get("REPRO_TAPE_RECOMPUTE_MIN", ""))


def _hold_bytes(store: str, ds_elems: int, itemsize: int = 4) -> int:
    """Held cotangent bytes between phases (the BK-specific residency; the
    activation copy aliases the standard tape for native/recompute and
    shrinks alongside ds when compressed). ``itemsize`` is the cotangent's
    native dtype width — a bf16 model holds 2 bytes/element natively, so
    the 'bf16' store is a no-op there, never a halving."""
    return {"native": itemsize * ds_elems,
            "bf16": min(2, itemsize) * ds_elems,
            "int8": ds_elems + 4, "recompute": 0, "stream": 0}[store]


def tape_plan(kind: str, act_shape, ds_shape, policy: str = "auto",
              method: str = "", itemsize: int = 4) -> TapePlan:
    """Residency decision for one tap's book-kept state.

    ``policy`` is the resolved request ('auto' lets the byte-threshold rule
    pick; an explicit store pins it but still reports its cost numbers).
    ``itemsize`` is the tap cotangent's dtype width (follows the model
    dtype — the engine threads it from the tap structure so the byte
    thresholds track the real footprint). ``recompute_flops`` models the
    phase-3 re-derivation: one backward from the loss down to this tap's
    site, ~2 * |ds| * d_in FLOPs for the site's own matmul chain."""
    if policy == "stream":
        # engine-assigned (not a user-requestable store): the tap belongs to
        # a streamed single-tap clip unit — phases 2+3 fuse at the tap, the
        # cotangent is consumed the moment it is produced, and NOTHING is
        # held between phases. Zero hold bytes, zero re-derivation, and the
        # REPRO_TAPE force does not apply (there is no record to store).
        return TapePlan("stream", 0, 0, int(itemsize))

    key = ("tape", kind, tuple(act_shape), tuple(ds_shape), policy, method,
           int(itemsize), backend()) + _tape_env()

    def mk():
        ds_elems = _prod(ds_shape)
        d_in = (act_shape[-1] if kind in ("mm", "moe")
                else ds_shape[-1])          # emb: cotangent feature dim
        flops = 2 * ds_elems * int(d_in)
        force, bf16_min, rec_min = _tape_env()
        store = force or policy
        if store == "auto":
            lo = int(bf16_min) if bf16_min else TAPE_BF16_MIN
            hi = int(rec_min) if rec_min else TAPE_RECOMPUTE_MIN
            nat = _hold_bytes("native", ds_elems, itemsize)
            store = ("recompute" if nat >= hi
                     else "bf16" if nat >= lo else "native")
        if store not in TAPE_STORES:
            raise ValueError(f"unknown tape store {store!r}; options: "
                             f"{TAPE_STORES} (or 'auto')")
        return TapePlan(store, _hold_bytes(store, ds_elems, itemsize), flops,
                        int(itemsize))

    return _cached(key, mk)


def fit_tape_budget(plans: dict, budget_bytes: int) -> dict:
    """Upgrade per-tap stores ({key: TapePlan}) biggest-first along
    native -> bf16 -> recompute until the total held bytes fit the budget
    (int8 stays opt-in: its stochastic error is a per-run choice, not a
    planner default). Returns a new {key: TapePlan} dict."""
    order = {"native": "bf16", "bf16": "recompute"}
    out = dict(plans)

    def total() -> int:
        return sum(p.hold_bytes for p in out.values())

    while total() > budget_bytes:
        cands = [(k, p) for k, p in out.items() if p.store in order]
        if not cands:
            break
        k, p = max(cands, key=lambda kp: kp[1].hold_bytes)
        per = {"native": p.itemsize, "bf16": min(2, p.itemsize)}[p.store]
        ds_elems = p.hold_bytes // per
        nxt = order[p.store]
        out[k] = TapePlan(nxt, _hold_bytes(nxt, ds_elems, p.itemsize),
                          p.recompute_flops, p.itemsize)
    return out


# ---------------------------------------------------------------- autotune
def _time(fn, *args, reps: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def autotune(run_fn, candidates, *args) -> tuple:
    """Measure ``run_fn(*args, **dict(cand))`` per candidate block tuple and
    return the fastest. Call OUTSIDE jit with concrete arrays; feed the
    winner back via the plan cache (see ``override_blocks``)."""
    best, best_t, last_err = None, float("inf"), None
    for cand in candidates:
        try:
            t = _time(functools.partial(run_fn, **dict(cand)), *args)
        except Exception as e:  # candidate invalid for this shape/backend
            last_err = e
            continue
        if t < best_t:
            best, best_t = cand, t
    if best is None:
        raise ValueError("no autotune candidate succeeded") from last_err
    return tuple(best)


def override_blocks(key_prefix: str, kind: str, act_shape, ds_shape,
                    blocks: tuple, mode: str = "bk", vocab: int = 0,
                    method: str = "") -> None:
    """Pin measured blocks for one (kind, shape): subsequent plans use them."""
    if key_prefix == "norm":
        plan = norm_plan(kind, act_shape, ds_shape, mode, method)
        key = ("norm", kind, tuple(act_shape), tuple(ds_shape), mode, method,
               backend())
    else:
        plan = grad_plan(kind, act_shape, ds_shape, vocab)
        key = ("grad", kind, tuple(act_shape), tuple(ds_shape), vocab, backend())
    _plan_cache[key + _env_state()] = Plan(plan.impl, plan.method,
                                           tuple(blocks))


def clear_cache() -> None:
    _plan_cache.clear()
