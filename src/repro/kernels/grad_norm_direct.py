"""Fused direct-norm Pallas kernel (TPU): per-sample squared gradient norms
via instantiation,

    n_b = sum_l || a_lb^T g_lb ||_F^2

computed (d,p)-tile by tile **without materializing the (B,d,p) per-sample
gradients in HBM** — removes the Bpd space term of module 4 (the reason
Opacus "cannot scale to large models"), so the MixOpt hybrid decision becomes
a pure time tradeoff.

Grid (B, L, d/bd, p/bp): stacked (L,B,T,d) records run as ONE kernel launch
via the L grid axis — out[b] stays resident while every (layer, tile) pair
accumulates into it."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _kernel(a_ref, g_ref, out_ref):
    l = pl.program_id(1)
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when((l == 0) & (i == 0) & (j == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[0, 0].astype(F32)              # (T, bd)
    g = g_ref[0, 0].astype(F32)              # (T, bp)
    tile = jax.lax.dot_general(a, g, (((0,), (0,)), ((), ())),
                               preferred_element_type=F32)  # (bd, bp)
    out_ref[0] += jnp.sum(tile * tile)


@functools.partial(jax.jit, static_argnames=("block_d", "block_p", "interpret"))
def grad_norm_direct(a, ds, block_d: int = 256, block_p: int = 256,
                     interpret: bool = False):
    """a (L,B,T,d) or (B,T,d), ds likewise -> (B,) f32."""
    if a.ndim == 3:
        a, ds = a[None], ds[None]
    L, B, T, d = a.shape
    p = ds.shape[-1]
    bd, bp = min(block_d, d), min(block_p, p)
    if d % bd:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, 0), (0, bd - d % bd)))
        d = a.shape[-1]
    if p % bp:
        ds = jnp.pad(ds, ((0, 0), (0, 0), (0, 0), (0, bp - p % bp)))
        p = ds.shape[-1]

    out = pl.pallas_call(
        _kernel,
        grid=(B, L, d // bd, p // bp),
        in_specs=[
            pl.BlockSpec((1, 1, T, bd), lambda b, l, i, j: (l, b, 0, i)),
            pl.BlockSpec((1, 1, T, bp), lambda b, l, i, j: (l, b, 0, j)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b, l, i, j: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), F32),
        interpret=interpret,
    )(a, ds)
    return out
