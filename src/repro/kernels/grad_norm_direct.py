"""Fused direct-norm Pallas kernel (TPU): per-sample squared gradient norms
via instantiation,

    n_b = || a_b^T g_b ||_F^2

computed (d,p)-tile by tile **without materializing the (B,d,p) per-sample
gradients in HBM** — removes the Bpd space term of module 4 (the reason
Opacus "cannot scale to large models"), so the MixOpt hybrid decision becomes
a pure time tradeoff. Grid (B, d/bd, p/bp)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _kernel(a_ref, g_ref, out_ref):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((i == 0) & (j == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[0].astype(F32)                 # (T, bd)
    g = g_ref[0].astype(F32)                 # (T, bp)
    tile = jax.lax.dot_general(a, g, (((0,), (0,)), ((), ())),
                               preferred_element_type=F32)  # (bd, bp)
    out_ref[0] += jnp.sum(tile * tile)


@functools.partial(jax.jit, static_argnames=("block_d", "block_p", "interpret"))
def grad_norm_direct(a, ds, block_d: int = 256, block_p: int = 256,
                     interpret: bool = False):
    """a (B,T,d), ds (B,T,p) -> (B,) f32."""
    B, T, d = a.shape
    p = ds.shape[-1]
    bd, bp = min(block_d, d), min(block_p, p)
    if d % bd:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, bd - d % bd)))
        d = a.shape[-1]
    if p % bp:
        ds = jnp.pad(ds, ((0, 0), (0, 0), (0, bp - p % bp)))
        p = ds.shape[-1]

    out = pl.pallas_call(
        _kernel,
        grid=(B, d // bd, p // bp),
        in_specs=[
            pl.BlockSpec((1, T, bd), lambda b, i, j: (b, 0, i)),
            pl.BlockSpec((1, T, bp), lambda b, i, j: (b, 0, j)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b, i, j: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), F32),
        interpret=interpret,
    )(a, ds)
    return out
