"""Fused norm+clip+grad Pallas kernel (TPU): the one-pass form of BK
Algorithm 1 lines 6-9 for a SINGLE-TAP clip unit (scope='layer'),

    g_b  = a_b^T ds_b          per-sample gradient        (L,d,p)
    n_b  = ||g_b||_F           per-sample norm            scalar
    C_b  = clip(n_b) * w_b     clip factor x batch mask   scalar
    G   += C_b * g_b           clipped weighted grad      (L,d,p)

in ONE grid pass over the batch: per grid step the whole per-sample
gradient lives in VMEM, the norm and clip factor are computed in-register,
and the weighted tile folds straight into the output accumulator. The
contraction a^T ds runs ONCE — this is the mixopt book-keeping trick
(cache the per-sample grad between the norm and weighting passes) without
the HBM cache, possible exactly because a layer-scope unit's clip decision
closes over this one tap.

Grid (B,): the leading L axis keeps stacked (L,B,T,d) records a single
launch, and the (L,d,p) working set is what the dispatch cost model
(``fused_plan``) checks against the VMEM budget before routing here.

Outputs: (G (L,d,p) f32, sq (B,) f32) — the per-sample SQUARED norms are
emitted too so the engine's norm telemetry / flat-vs-layer diagnostics see
the same numbers as the two-phase path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _kernel(a_ref, g_ref, w_ref, out_ref, sq_ref, *, clip):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[:, 0].astype(F32)               # (L, T, d)
    ds = g_ref[:, 0].astype(F32)              # (L, T, p)
    # batched over L, contract T: per-sample grad for the WHOLE stacked unit
    g = jax.lax.dot_general(a, ds, (((1,), (1,)), ((0,), (0,))),
                            preferred_element_type=F32)     # (L, d, p)
    sq = jnp.sum(g * g)
    c = clip(jnp.sqrt(sq)).astype(F32) * w_ref[0].astype(F32)
    sq_ref[0] = sq
    out_ref[...] += c * g


@functools.partial(jax.jit,
                   static_argnames=("clipping", "R", "gamma", "interpret"))
def fused_clip_grad(a, ds, w, clipping: str, R: float, gamma: float,
                    interpret: bool = False):
    """a (L,B,T,d) or (B,T,d), ds likewise (last dim p), w (B,) per-sample
    weight (batch-pad mask) -> (G (L,d,p) or (d,p) f32, sq (B,) f32).

    ``clipping``/``R``/``gamma`` are static and build the clip fn via
    :func:`repro.core.clipping.get_clip_fn` — it runs on a scalar inside
    the kernel body (jnp scalar ops lower fine under Pallas)."""
    from repro.core.clipping import get_clip_fn
    kw = {"gamma": gamma} if clipping == "automatic" else {}
    clip = get_clip_fn(clipping, R, **kw)

    squeeze = a.ndim == 3
    if squeeze:
        a, ds = a[None], ds[None]
    L, B, T, d = a.shape
    p = ds.shape[-1]
    # lane-align the contraction dims; zero pads are norm/grad-neutral
    pd_, pp_ = (-d) % 128, (-p) % 128
    if pd_:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, 0), (0, pd_)))
    if pp_:
        ds = jnp.pad(ds, ((0, 0), (0, 0), (0, 0), (0, pp_)))
    D, P = a.shape[-1], ds.shape[-1]

    out, sq = pl.pallas_call(
        functools.partial(_kernel, clip=clip),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((L, 1, T, D), lambda b: (0, b, 0, 0)),
            pl.BlockSpec((L, 1, T, P), lambda b: (0, b, 0, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((L, D, P), lambda b: (0, 0, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, D, P), F32),
            jax.ShapeDtypeStruct((B,), F32),
        ],
        interpret=interpret,
    )(a, ds, w.astype(F32))
    out = out[:, :d, :p]
    return (out[0] if squeeze else out), sq
