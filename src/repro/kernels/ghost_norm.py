"""Fused ghost-norm Pallas kernel (TPU): per-sample squared gradient norms

    n_b = sum_l sum_{t,t'} (a_lbt . a_lbt') (g_lbt . g_lbt')

computed tile-by-tile in VMEM, **never materializing the (B,T,T) Gram
matrices in HBM** — this removes the paper's 2BT^2 space term (Table 3,
module 3) entirely.

Grid (B, L, tri(nt)): the (i, j) tile pairs are enumerated over a *packed
lower triangle* — a scalar-prefetched (ntri, 2) index table drives the block
index maps, so only the j <= i tiles are ever fetched (off-diagonal tiles
count twice by symmetry). The old square grid fetched all nt^2 tile pairs
and discarded half behind ``pl.when(j <= i)``; packing the triangle halves
the HBM traffic of the norm pass. Stacked (L, B, T, d) records run as ONE
kernel launch via the L grid axis (out[b] accumulates across layers).

Beyond-paper: the paper's GhostClip/BK stores both Grams (2BT^2 floats).
Here VMEM holds 2*bt*(d+p) + 2*bt^2 floats per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


@functools.lru_cache(maxsize=None)
def tri_table(nt: int) -> np.ndarray:
    """Packed lower-triangle enumeration: (ntri, 2) int32 with j <= i."""
    return np.array([(i, j) for i in range(nt) for j in range(i + 1)],
                    dtype=np.int32)


def _kernel(ij_ref, ai_ref, aj_ref, gi_ref, gj_ref, out_ref):
    l = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((l == 0) & (k == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ai = ai_ref[0, 0].astype(F32)           # (bt, d)
    aj = aj_ref[0, 0].astype(F32)
    gi = gi_ref[0, 0].astype(F32)           # (bt, p)
    gj = gj_ref[0, 0].astype(F32)
    gram_a = jax.lax.dot_general(ai, aj, (((1,), (1,)), ((), ())),
                                 preferred_element_type=F32)
    gram_g = jax.lax.dot_general(gi, gj, (((1,), (1,)), ((), ())),
                                 preferred_element_type=F32)
    contrib = jnp.sum(gram_a * gram_g)
    scale = jnp.where(ij_ref[k, 0] == ij_ref[k, 1], 1.0, 2.0)
    out_ref[0] += scale * contrib


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def ghost_norm(a, ds, block_t: int = 128, interpret: bool = False):
    """a (L,B,T,d) or (B,T,d), ds likewise -> per-sample sq norms (B,) f32."""
    if a.ndim == 3:
        a, ds = a[None], ds[None]
    L, B, T, d = a.shape
    p = ds.shape[-1]
    bt = min(block_t, T)
    if T % bt:
        pad = bt - T % bt
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0)))
        ds = jnp.pad(ds, ((0, 0), (0, 0), (0, pad), (0, 0)))
        T = a.shape[2]
    nt = T // bt
    ij = jnp.asarray(tri_table(nt))
    ntri = ij.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, L, ntri),
        in_specs=[
            pl.BlockSpec((1, 1, bt, d), lambda b, l, k, ij: (l, b, ij[k, 0], 0)),
            pl.BlockSpec((1, 1, bt, d), lambda b, l, k, ij: (l, b, ij[k, 1], 0)),
            pl.BlockSpec((1, 1, bt, p), lambda b, l, k, ij: (l, b, ij[k, 0], 0)),
            pl.BlockSpec((1, 1, bt, p), lambda b, l, k, ij: (l, b, ij[k, 1], 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b, l, k, ij: (b,)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B,), F32),
        interpret=interpret,
    )(ij, a, a, ds, ds)
