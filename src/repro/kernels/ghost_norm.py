"""Fused ghost-norm Pallas kernel (TPU): per-sample squared gradient norms

    n_b = sum_{t,t'} (a_bt . a_bt') (g_bt . g_bt')

computed tile-by-tile in VMEM, **never materializing the (B,T,T) Gram
matrices in HBM** — this removes the paper's 2BT^2 space term (Table 3,
module 3) entirely. Grid (B, T/bt, T/bt'); each step forms the (bt, bt')
Gram tiles of both factors on the MXU and accumulates their Frobenius inner
product into out[b]. Symmetry: only j<=i tiles are visited (off-diagonal
tiles count twice).

Beyond-paper: the paper's GhostClip/BK stores both Grams (2BT^2 floats).
Here VMEM holds 2*bt*max(d,p) + bt^2 floats per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _kernel(ai_ref, aj_ref, gi_ref, gj_ref, out_ref):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((i == 0) & (j == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(j <= i)
    def _accum():
        ai = ai_ref[0].astype(F32)          # (bt, d)
        aj = aj_ref[0].astype(F32)
        gi = gi_ref[0].astype(F32)          # (bt, p)
        gj = gj_ref[0].astype(F32)
        gram_a = jax.lax.dot_general(ai, aj, (((1,), (1,)), ((), ())),
                                     preferred_element_type=F32)
        gram_g = jax.lax.dot_general(gi, gj, (((1,), (1,)), ((), ())),
                                     preferred_element_type=F32)
        contrib = jnp.sum(gram_a * gram_g)
        scale = jnp.where(j == i, 1.0, 2.0)  # symmetric off-diagonal tiles
        out_ref[0] += scale * contrib


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def ghost_norm(a, ds, block_t: int = 128, interpret: bool = False):
    """a (B,T,d), ds (B,T,p) -> per-sample squared norms (B,) f32."""
    B, T, d = a.shape
    p = ds.shape[-1]
    bt = min(block_t, T)
    if T % bt:
        pad = bt - T % bt
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        ds = jnp.pad(ds, ((0, 0), (0, pad), (0, 0)))
        T = a.shape[1]
    nt = T // bt

    grid = (B, nt, nt)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bt, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bt, p), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bt, p), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b, i, j: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), F32),
        interpret=interpret,
    )(a, a, ds, ds)
    return out
