"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematical definition, written for clarity not speed;
kernel tests sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def ghost_norm_ref(a, ds):
    """Per-sample sq norm via the ghost trick. a (B,T,d), ds (B,T,p) -> (B,)."""
    a, ds = a.astype(F32), ds.astype(F32)
    ga = jnp.einsum("btd,bsd->bts", a, a)
    gg = jnp.einsum("btp,bsp->bts", ds, ds)
    return jnp.einsum("bts,bts->b", ga, gg)


def grad_norm_direct_ref(a, ds):
    """Per-sample sq norm via instantiation. a (B,T,d), ds (B,T,p) -> (B,)."""
    g = jnp.einsum("btd,btp->bdp", a.astype(F32), ds.astype(F32))
    return jnp.einsum("bdp,bdp->b", g, g)


def clipped_grad_ref(a, C, ds):
    """G = a^T diag(C) ds. a (B,T,d), C (B,), ds (B,T,p) -> (d,p) f32."""
    return jnp.einsum("btd,b,btp->dp", a.astype(F32), C.astype(F32),
                      ds.astype(F32))


def flash_attention_ref(q, k, v, causal=True):
    """q (B,T,H,h), k/v (B,S,K,h), H = K*G -> (B,T,H,h). Plain softmax."""
    B, T, H, h = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, T, K, G, h).astype(F32)
    logits = jnp.einsum("btkgh,bskh->bkgts", qg, k.astype(F32)) / (h ** 0.5)
    if causal:
        mask = jnp.arange(T)[:, None] >= jnp.arange(S)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", p, v.astype(F32))
    return out.reshape(B, T, H, h).astype(q.dtype)


def wkv6_ref(r, k, v, w, u):
    """RWKV6 recurrence. r,k,v,w (B,T,H,h); u (H,h) -> (B,T,H,h) f32."""
    from repro.models.rwkv6 import wkv6_ref as _m
    return _m(r, k, v, w, u)
