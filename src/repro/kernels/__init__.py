# Fused Pallas kernel surface for the BK engine (plus model hot-spots).
#   mechanism: ghost_norm / grad_norm_direct / clipped_grad (mm taps),
#              emb_norm / emb_grad (embedding taps), moe_ghost (moe taps),
#              flash_attention, wkv6 — thin jit wrappers in ops.py
#   policy:    dispatch.py — per-tap kernel-vs-jnp choice + block autotune
#   contract:  ref.py pure-jnp oracles; tests/test_kernel_parity.py sweeps
