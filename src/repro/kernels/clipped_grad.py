"""Fused clipped-gradient Pallas kernel (TPU): BK Algorithm 1 line 9,

    G = sum_b C_b * a_b^T g_b   =  a^T diag(C) g

with the clip factor applied in-register — avoids writing the (B,T,p)
intermediate C*ds back to HBM that the einsum formulation materializes.
Grid (d/bd, p/bp, B); B innermost so each (d,p) tile accumulates over samples
in VMEM and is written once."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _kernel(a_ref, g_ref, c_ref, out_ref):
    b = pl.program_id(2)

    @pl.when(b == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[0].astype(F32)                  # (T, bd)
    g = g_ref[0].astype(F32)                  # (T, bp)
    c = c_ref[0].astype(F32)                  # scalar clip factor
    tile = jax.lax.dot_general(a * c, g, (((0,), (0,)), ((), ())),
                               preferred_element_type=F32)
    out_ref[...] += tile


@functools.partial(jax.jit, static_argnames=("block_d", "block_p", "interpret"))
def clipped_grad(a, C, ds, block_d: int = 256, block_p: int = 256,
                 interpret: bool = False):
    """a (B,T,d), C (B,), ds (B,T,p) -> (d,p) f32."""
    B, T, d = a.shape
    p = ds.shape[-1]
    bd, bp = min(block_d, d), min(block_p, p)
    pd_, pp_ = (bd - d % bd) % bd, (bp - p % bp) % bp
    if pd_:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pd_)))
    if pp_:
        ds = jnp.pad(ds, ((0, 0), (0, 0), (0, pp_)))
    D, P = a.shape[-1], ds.shape[-1]

    out = pl.pallas_call(
        _kernel,
        grid=(D // bd, P // bp, B),
        in_specs=[
            pl.BlockSpec((1, T, bd), lambda i, j, b: (b, 0, i)),
            pl.BlockSpec((1, T, bp), lambda i, j, b: (b, 0, j)),
            pl.BlockSpec((1,), lambda i, j, b: (b,)),
        ],
        out_specs=pl.BlockSpec((bd, bp), lambda i, j, b: (i, j)),
        out_shape=jax.ShapeDtypeStruct((D, P), F32),
        interpret=interpret,
    )(a, ds, C)
    return out[:d, :p]
