"""Fused clipped-gradient Pallas kernel (TPU): BK Algorithm 1 line 9,

    G_l = sum_b C_b * a_lb^T g_lb   =  a_l^T diag(C) g_l

with the clip factor applied in-register — avoids writing the (B,T,p)
intermediate C*ds back to HBM that the einsum formulation materializes.

Grid (L, d/bd, p/bp, B): B innermost so each (l, d, p) tile accumulates over
samples in VMEM and is written once; the leading L axis makes stacked
(L,B,T,d) records a SINGLE kernel launch (the old wrapper re-launched the
kernel through jax.vmap once per layer)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _kernel(a_ref, g_ref, c_ref, out_ref):
    b = pl.program_id(3)

    @pl.when(b == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[0, 0].astype(F32)               # (T, bd)
    g = g_ref[0, 0].astype(F32)               # (T, bp)
    c = c_ref[0].astype(F32)                  # scalar clip factor
    tile = jax.lax.dot_general(a * c, g, (((0,), (0,)), ((), ())),
                               preferred_element_type=F32)
    out_ref[0] += tile


@functools.partial(jax.jit, static_argnames=("block_d", "block_p", "interpret"))
def clipped_grad(a, C, ds, block_d: int = 256, block_p: int = 256,
                 interpret: bool = False):
    """a (L,B,T,d) or (B,T,d), C (B,), ds likewise -> (L,d,p) or (d,p) f32."""
    squeeze = a.ndim == 3
    if squeeze:
        a, ds = a[None], ds[None]
    L, B, T, d = a.shape
    p = ds.shape[-1]
    bd, bp = min(block_d, d), min(block_p, p)
    pd_, pp_ = (bd - d % bd) % bd, (bp - p % bp) % bp
    if pd_:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, 0), (0, pd_)))
    if pp_:
        ds = jnp.pad(ds, ((0, 0), (0, 0), (0, 0), (0, pp_)))
    D, P = a.shape[-1], ds.shape[-1]

    out = pl.pallas_call(
        _kernel,
        grid=(L, D // bd, P // bp, B),
        in_specs=[
            pl.BlockSpec((1, 1, T, bd), lambda l, i, j, b: (l, b, 0, i)),
            pl.BlockSpec((1, 1, T, bp), lambda l, i, j, b: (l, b, 0, j)),
            pl.BlockSpec((1,), lambda l, i, j, b: (b,)),
        ],
        out_specs=pl.BlockSpec((1, bd, bp), lambda l, i, j, b: (l, i, j)),
        out_shape=jax.ShapeDtypeStruct((L, D, P), F32),
        interpret=interpret,
    )(a, ds, C)
    out = out[:, :d, :p]
    return out[0] if squeeze else out
