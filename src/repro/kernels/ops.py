"""Jit'd wrappers around the Pallas kernels, with the layout handling the DP
engine expects (stacked layer dims, padding, moe record dicts) and automatic
interpret-mode on CPU (kernels are validated on CPU via interpret=True; TPU
v5e is the compile target). Policy — which kernel, which blocks — lives in
repro.kernels.dispatch; these wrappers are pure mechanism."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.clipped_grad import clipped_grad as _clipped_grad
from repro.kernels.fused_clip import fused_clip_grad as _fused_clip
from repro.kernels.emb_grad import emb_clipped_grad as _emb_grad
from repro.kernels.emb_norm import emb_ghost_norm as _emb_norm
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.ghost_norm import ghost_norm as _ghost_norm
from repro.kernels.grad_norm_direct import grad_norm_direct as _direct
from repro.kernels.moe_ghost import (moe_clipped_grad as _moe_grad,
                                     moe_direct_norm as _moe_direct,
                                     moe_ghost_norm as _moe_ghost)
from repro.kernels.wkv6 import wkv6 as _wkv6


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ------------------------------------------------------------------ mm taps
def ghost_norm_mm(a, ds, block_t: int = 128):
    """(B,T,d)/(L,B,T,d) records -> per-sample sq norms (B,)."""
    return _ghost_norm(a, ds, block_t=block_t, interpret=_interpret())


def direct_norm_mm(a, ds, block_d: int = 256, block_p: int = 256):
    return _direct(a, ds, block_d=block_d, block_p=block_p,
                   interpret=_interpret())


def clipped_grad_mm(a, C, ds, block_d: int = 256, block_p: int = 256):
    """-> (d,p) f32, or (L,d,p) for stacked records. One launch either way."""
    return _clipped_grad(a, C, ds, block_d=block_d, block_p=block_p,
                         interpret=_interpret())


def fused_clip_grad_mm(a, ds, w, clipping: str, R: float, gamma: float):
    """One-pass norm+clip+grad for a streamed single-tap unit (scope=
    'layer'): -> (G (d,p)/(L,d,p) f32, sq_norms (B,) f32). ``w`` is the
    per-sample weight (batch-pad mask) folded into the clip factors."""
    return _fused_clip(a, ds, w, clipping=clipping, R=float(R),
                       gamma=float(gamma), interpret=_interpret())


# ----------------------------------------------------------------- emb taps
def ghost_norm_emb(ids, ds, block_t: int = 128):
    """ids (B,T)/(L,B,T) int, ds (B,T,d)/(L,B,T,d) -> (B,)."""
    return _emb_norm(ids, ds, block_t=block_t, interpret=_interpret())


def clipped_grad_emb(ids, C, ds, vocab: int, block_v: int = 512):
    """-> (vocab,d) f32, or (L,vocab,d) for stacked records."""
    return _emb_grad(ids, C, ds, vocab=vocab, block_v=block_v,
                     interpret=_interpret())


# ----------------------------------------------------------------- moe taps
def ghost_norm_moe(rec, ds):
    """rec {'a': (B,E,C,d)[+L], 'mask': (B,E,C)[+L]}, ds (B,E,C,p)[+L] -> (B,)."""
    return _moe_ghost(rec["a"], rec["mask"], ds, interpret=_interpret())


def direct_norm_moe(rec, ds, block_d: int = 256, block_p: int = 256):
    return _moe_direct(rec["a"], rec["mask"], ds, block_d=block_d,
                       block_p=block_p, interpret=_interpret())


def clipped_grad_moe(rec, C, ds, block_d: int = 256, block_p: int = 256):
    """-> (E,d,p) f32, or (L,E,d,p) for stacked records. One launch."""
    return _moe_grad(rec["a"], rec["mask"], C, ds, block_d=block_d,
                     block_p=block_p, interpret=_interpret())


# ------------------------------------------------------------------- others
def flash_attention(q, k, v, causal=True, block_q=128, block_k=128):
    return _flash(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
                  interpret=_interpret())


def wkv6(r, k, v, w, u, chunk: int = 16):
    return _wkv6(r, k, v, w, u, chunk=chunk, interpret=_interpret())
