"""Jit'd wrappers around the Pallas kernels, with the layout handling the DP
engine expects (stacked layer dims, padding) and automatic interpret-mode on
CPU (kernels are validated on CPU via interpret=True; TPU v5e is the compile
target)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.clipped_grad import clipped_grad as _clipped_grad
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.ghost_norm import ghost_norm as _ghost_norm
from repro.kernels.grad_norm_direct import grad_norm_direct as _direct
from repro.kernels.wkv6 import wkv6 as _wkv6


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def ghost_norm_mm(a, ds, block_t: int = 128):
    """(B,T,d)/(L,B,T,d) records -> per-sample sq norms (B,)."""
    if a.ndim == 4:
        L, B = a.shape[0], a.shape[1]
        n = _ghost_norm(a.reshape((L * B,) + a.shape[2:]),
                        ds.reshape((L * B,) + ds.shape[2:]),
                        block_t=block_t, interpret=_interpret())
        return n.reshape(L, B).sum(0)
    return _ghost_norm(a, ds, block_t=block_t, interpret=_interpret())


def direct_norm_mm(a, ds, block_d: int = 256, block_p: int = 256):
    if a.ndim == 4:
        L, B = a.shape[0], a.shape[1]
        n = _direct(a.reshape((L * B,) + a.shape[2:]),
                    ds.reshape((L * B,) + ds.shape[2:]),
                    block_d=block_d, block_p=block_p, interpret=_interpret())
        return n.reshape(L, B).sum(0)
    return _direct(a, ds, block_d=block_d, block_p=block_p,
                   interpret=_interpret())


def clipped_grad_mm(a, C, ds, block_d: int = 256, block_p: int = 256):
    """-> (d,p) f32, or (L,d,p) for stacked records."""
    if a.ndim == 4:
        fn = lambda al, dsl: _clipped_grad(al, C, dsl, block_d=block_d,
                                           block_p=block_p,
                                           interpret=_interpret())
        return jax.vmap(fn)(a, ds)
    return _clipped_grad(a, C, ds, block_d=block_d, block_p=block_p,
                         interpret=_interpret())


def flash_attention(q, k, v, causal=True, block_q=128, block_k=128):
    return _flash(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
                  interpret=_interpret())


def wkv6(r, k, v, w, u, chunk: int = 16):
    return _wkv6(r, k, v, w, u, chunk=chunk, interpret=_interpret())
