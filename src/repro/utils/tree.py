"""Pytree path utilities.

Params are nested dicts of jnp arrays. Paths are '/'-joined key strings,
e.g. ``blocks/attn/qkv/w``. The DP engine partitions parameter leaves into
"ghost" weights (owned by a tapped generalized-linear op; path is
``<tap key>/w``) and "per-sample" (psp) leaves (biases, norm scales, decay
vectors, ...) which are broadcast to a leading batch dim before
differentiation so their cotangents are per-sample gradients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flatten(tree: dict, prefix: str = "") -> dict:
    """Nested dict -> flat {path: leaf}."""
    out = {}
    for k, v in tree.items():
        path = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            out.update(flatten(v, path))
        else:
            out[path] = v
    return out


def unflatten(flat: dict) -> dict:
    out: dict = {}
    for path, leaf in flat.items():
        keys = path.split("/")
        node = out
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = leaf
    return out


def tree_size(tree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def tree_map_with_path(fn, tree: dict, prefix: str = "") -> dict:
    """Map fn(path, leaf) over a nested dict, preserving structure."""
    out = {}
    for k, v in tree.items():
        path = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            out[path.split("/")[-1]] = tree_map_with_path(fn, v, path)
        else:
            out[path.split("/")[-1]] = fn(path, v)
    return out


def merge_flat(base_flat: dict, override_flat: dict) -> dict:
    merged = dict(base_flat)
    merged.update(override_flat)
    return merged
