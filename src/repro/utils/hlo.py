"""Trip-aware analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop (lax.scan) bodies ONCE,
which silently undercounts layer-scanned / microbatch-accumulated programs
by orders of magnitude. This module parses the optimized HLO, recovers the
while-loop trip counts from their condition computations, and aggregates

  * matmul FLOPs (dot ops, including inside fusions),
  * HBM traffic proxy (operand + result bytes of every top-level op),
  * collective wire bytes per op type,

each multiplied by the product of enclosing loop trip counts. These feed the
roofline terms in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-_]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\s*\{\s*"n"\s*:\s*"(\d+)"')
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-_]+)\s*=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:{[^}]*})?))\s*"
    r"([\w\-]+)\((.*)$")
_CALLED_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-_]+)")
_OPERAND_RE = re.compile(r"%([\w.\-_]+)")


def _shape_dims(txt):
    out = []
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x]
        out.append((dt, d))
    return out


def _shape_bytes(txt) -> int:
    total = 0
    for dt, dims in _shape_dims(txt):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Op:
    name: str
    result: str
    opcode: str
    rest: str
    operands: list = field(default_factory=list)


@dataclass
class _Comp:
    name: str
    ops: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)  # value name -> result txt


def _parse(hlo: str):
    comps = {}
    cur = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = _Comp(m.group(1))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, result, opcode, rest = m.groups()
        args = rest.split(")", 1)[0]
        op = _Op(name, result, opcode, rest,
                 operands=_OPERAND_RE.findall(args))
        cur.shapes[name] = result
        cur.ops.append(op)
    return comps


def _dot_flops(op: _Op, comp: _Comp) -> float:
    """2 * prod(result dims) * contraction size (first contracting dim set)."""
    shapes = _shape_dims(op.result)
    if not shapes:
        return 0.0
    _, rdims = shapes[0]
    n_out = 1
    for d in rdims:
        n_out *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    k = 1
    if m and op.operands:
        lhs_shape = comp.shapes.get(op.operands[0], "")
        ls = _shape_dims(lhs_shape)
        if ls:
            dims = ls[0][1]
            for ix in m.group(1).split(","):
                if ix and int(ix) < len(dims):
                    k *= dims[int(ix)]
    return 2.0 * n_out * k


def _trip_count(cond: _Comp) -> int:
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", "constant(" + op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


class HloAnalysis:
    def __init__(self, hlo_text: str):
        self.comps = _parse(hlo_text)
        self.entry = self._find_entry(hlo_text)
        self._memo = {}

    def _find_entry(self, hlo: str):
        m = re.search(r"ENTRY\s+%?([\w.\-_]+)", hlo)
        return m.group(1) if m else next(iter(self.comps), None)

    def _cost(self, cname: str):
        """-> (flops, traffic_bytes, {collective: bytes}) for one execution."""
        if cname in self._memo:
            return self._memo[cname]
        comp = self.comps.get(cname)
        if comp is None:
            return 0.0, 0.0, {}
        flops = 0.0
        traffic = 0.0
        coll = defaultdict(float)
        self._memo[cname] = (0.0, 0.0, {})  # cycle guard
        for op in comp.ops:
            called = _CALLED_RE.findall(op.rest)
            if op.opcode == "while":
                body, cond = None, None
                mb = re.search(r"body=%?([\w.\-_]+)", op.rest)
                mc = re.search(r"condition=%?([\w.\-_]+)", op.rest)
                if mb:
                    body = mb.group(1)
                if mc:
                    cond = mc.group(1)
                mt = _TRIP_RE.search(op.rest)
                if mt:  # XLA records the static trip count directly
                    trips = int(mt.group(1))
                else:
                    trips = _trip_count(self.comps[cond]) if cond in self.comps else 1
                bf, bt, bc = self._cost(body) if body else (0, 0, {})
                flops += trips * bf
                traffic += trips * bt
                for k, v in bc.items():
                    coll[k] += trips * v
                continue
            if op.opcode in ("fusion", "call", "conditional", "map",
                             "reduce", "reduce-window", "sort", "scatter",
                             "select-and-scatter", "custom-call"):
                for sub in called:
                    sf, st, sc = self._cost(sub)
                    flops += sf
                    # interior of a fusion is one kernel: no extra traffic
                    if op.opcode in ("call", "conditional"):
                        traffic += st
                    for k, v in sc.items():
                        coll[k] += v
            if op.opcode == "dot":
                flops += _dot_flops(op, comp)
            base = op.opcode.replace("-start", "")
            if base in COLLECTIVES and not op.opcode.endswith("-done"):
                coll[base] += _shape_bytes(op.result)
            traffic += self._op_traffic(op, comp)
        out = (flops, traffic, dict(coll))
        self._memo[cname] = out
        return out

    # ------------------------------------------------------------ traffic
    # Perfect-fusion HBM model (TPU roofline convention): charge the ops
    # whose inputs/outputs MUST round-trip HBM — matmuls, windowed reads,
    # in-place updates, reductions, collectives — and assume elementwise /
    # copy / convert work fuses into its producers (true on TPU; the CPU
    # backend's materialized f32 legalization copies are ignored).
    _WINDOW_OPS = ("dynamic-slice", "slice", "gather")

    def _op_traffic(self, op: _Op, comp: _Comp) -> float:
        oc = op.opcode
        res = _shape_bytes(op.result)
        if oc in self._WINDOW_OPS:
            return 2.0 * res
        if oc == "dynamic-update-slice":
            upd = _shape_bytes(comp.shapes.get(op.operands[1], "")) if \
                len(op.operands) > 1 else res
            return 2.0 * upd
        if oc == "scatter":
            upd = _shape_bytes(comp.shapes.get(op.operands[2], "")) if \
                len(op.operands) > 2 else res
            return 2.0 * upd
        if oc == "dot":
            total = float(res)
            for o in op.operands:
                total += _shape_bytes(comp.shapes.get(o, ""))
            return total
        if oc == "reduce" or oc.startswith("all-"):
            total = float(res)
            for o in op.operands:
                total += _shape_bytes(comp.shapes.get(o, ""))
            return total
        if oc == "fusion":
            m = re.search(r"calls=%?([\w.\-_]+)", op.rest)
            sub = self.comps.get(m.group(1)) if m else None
            if sub is None:
                return 0.0
            interior = {o.opcode for o in sub.ops}
            total = 0.0
            if "dynamic-update-slice" in interior:
                for o in sub.ops:
                    if o.opcode == "dynamic-update-slice" and len(o.operands) > 1:
                        total += 2.0 * _shape_bytes(sub.shapes.get(o.operands[1], ""))
            for o in sub.ops:
                if o.opcode in self._WINDOW_OPS:
                    total += 2.0 * _shape_bytes(o.result)
                elif o.opcode in ("dot", "reduce"):
                    total += _shape_bytes(o.result)
                    for od in o.operands:
                        total += _shape_bytes(sub.shapes.get(od, ""))
            # reduction-style fusion with big inputs, small output (e.g. the
            # norm-phase sum-of-squares): charge the streamed input once
            if total == 0.0 and "reduce" not in interior:
                big_in = sum(_shape_bytes(comp.shapes.get(o, ""))
                             for o in op.operands)
                if big_in > 4 * res:
                    total = float(res) + big_in
            return total
        return 0.0

    def _param_window_bytes(self, sub: _Comp, index: int):
        """If fusion parameter `index` is consumed only by window/update ops,
        return the touched bytes; else None."""
        pname = None
        for o in sub.ops:
            if o.opcode == "parameter" and f"parameter({index})" in o.rest:
                pname = o.name
                break
        if pname is None:
            return None
        touched = 0.0
        for o in sub.ops:
            if pname not in o.operands:
                continue
            if o.opcode in self._WINDOW_OPS:
                touched += _shape_bytes(o.result)
            elif o.opcode == "dynamic-update-slice" and o.operands and \
                    o.operands[0] == pname:
                upd = _shape_bytes(sub.shapes.get(o.operands[1], ""))
                touched += upd
            else:
                return None
        return touched if touched else None

    def totals(self):
        flops, traffic, coll = self._cost(self.entry)
        coll = dict(coll)
        coll["total"] = sum(coll.values())
        return {"flops": flops, "traffic_bytes": traffic,
                "collectives": coll}


def xla_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions: older
    releases return one dict, newer ones a one-element list of per-program
    dicts (and either may be empty). Always returns a plain dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def analyze_hlo(hlo_text: str) -> dict:
    return HloAnalysis(hlo_text).totals()


def collective_bytes(hlo_text: str) -> dict:
    """Trip-aware collective buffer bytes per op type (+ 'total')."""
    return analyze_hlo(hlo_text)["collectives"]
