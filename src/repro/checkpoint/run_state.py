"""Versioned RunState schema: everything a privacy-exact restart needs.

A checkpoint is privacy-exact when the resumed run is indistinguishable —
to the DP adversary observing released noisy quantities — from the run
that never crashed. That needs more than params: the full RunState is

    array payload (process-sliced npz, see checkpoint.checkpoint)
      params        model parameters
      opt           optimizer state (for DP-FTRL: the anchor ``theta0``,
                    noisy gradient prefix ``sum``, momentum ``m`` — i.e.
                    the tree position is (opt state, absolute step))
      step          last completed absolute step (scalar)
      rng           the BASE PRNG key of the TrainState — each step folds
                    its own index in, so (rng, step) replays the exact
                    per-step key sequence

    manifest meta (json, this module's schema)
      run_state_version   schema version (this file: 1)
      noise               NoiseMechanism.state_dict() — mechanism kind +
                          the config that keys its draws (tree seed,
                          restart period, completion flag)
      ledger              PrivacyLedger.to_json() — absolute steps
                          accounted + sigma / sampling / mechanism history
      pipeline            Pipeline.state_dict() — the generative config;
                          the cursor itself IS the step (batch(step) is a
                          pure function)
      config              run-config fingerprint for drift detection

On resume, drift in a PRIVACY_CRITICAL config key raises (continuing would
change the release the ledger claims to account); any other drift only
warns (e.g. extending ``steps`` is a legitimate continuation — the ledger
keeps counting). The noise mechanism and pipeline validate their own
state via ``load_state`` and raise on drift themselves.
"""
from __future__ import annotations

import hashlib

import jax
import numpy as np

from repro.core.accounting import PrivacyLedger
from repro.utils.tree import flatten

RUN_STATE_VERSION = 1

# Resuming with any of these changed alters the mechanism mid-release: the
# per-step keys (seed), the noise magnitude (sigma), the sensitivity unit
# and sampling (global_batch), the optimizer consuming the release, or the
# epoch structure of the tree (restart_every). The ledger's past entries
# would then describe a different mechanism than the one continuing.
PRIVACY_CRITICAL = ("seed", "sigma", "global_batch", "optimizer",
                    "restart_every", "noise", "mode")


def config_fingerprint(tc, policy, restart_every: int) -> dict:
    """The drift-detection view of a run config (json-able scalars only)."""
    return {
        "seed": int(tc.seed),
        "sigma": float(policy.sigma),
        "global_batch": int(tc.global_batch),
        "optimizer": str(tc.optimizer),
        "restart_every": int(restart_every),
        "noise": str(policy.noise),
        "mode": str(policy.mode),
        "steps": int(tc.steps),
        "seq_len": int(tc.seq_len),
        "lr": float(tc.lr),
        "microbatch": int(tc.microbatch),
    }


def pack_meta(mechanism, ledger: PrivacyLedger, pipeline,
              config: dict) -> dict:
    """The manifest-meta half of a RunState checkpoint."""
    return {
        "run_state_version": RUN_STATE_VERSION,
        "noise": mechanism.state_dict(),
        "ledger": ledger.to_json(),
        "pipeline": pipeline.state_dict(),
        "config": config,
    }


def check_resume(meta: dict, mechanism, pipeline, config: dict,
                 log=print) -> PrivacyLedger:
    """Validate a checkpoint's meta against the resuming run and return the
    restored ledger. Raises on privacy-critical drift; warns otherwise."""
    version = meta.get("run_state_version")
    if version != RUN_STATE_VERSION:
        raise ValueError(
            f"checkpoint run_state_version={version!r}; this build resumes "
            f"version {RUN_STATE_VERSION}")
    mechanism.load_state(meta["noise"])
    pipeline.load_state(meta["pipeline"])
    saved = meta.get("config", {})
    drift = {k: (saved.get(k), config[k]) for k in config
             if k in saved and saved[k] != config[k]}
    critical = {k: v for k, v in drift.items() if k in PRIVACY_CRITICAL}
    if critical:
        raise ValueError(
            "privacy-critical config drift between checkpoint and resumed "
            "run (checkpointed != configured): "
            + ", ".join(f"{k}: {a!r} != {b!r}"
                        for k, (a, b) in sorted(critical.items())))
    for k, (a, b) in sorted(drift.items()):
        log(f"resume config drift (non-critical) {k}: {a!r} -> {b!r}")
    return PrivacyLedger.from_json(meta.get("ledger"))


def params_digest(params) -> str:
    """Order-stable sha256 over every parameter's bytes — the bitwise
    restart-parity witness the elastic-restart tests and the CI crash/resume
    stage compare."""
    h = hashlib.sha256()
    for path in sorted(flat := flatten(params)):
        h.update(path.encode())
        h.update(np.ascontiguousarray(jax.device_get(flat[path])).tobytes())
    return h.hexdigest()
