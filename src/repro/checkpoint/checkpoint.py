"""Fault-tolerant, process-sliced checkpointing.

Format (v2): a checkpoint directory holds one or more shard payload files
plus a manifest —

    step_0000000042/
        shards.00000.npz    # process 0's addressable slices
        [shards.00001.npz]  # further processes on a multi-host pod
        manifest.json       # written LAST; global shapes + slice index

Every leaf is stored as its set of UNIQUE addressable shard slices, each
keyed by its global offset, with the leaf's GLOBAL shape/dtype recorded in
the manifest. Restore re-assembles the global arrays from whatever slice
decomposition the saving topology produced and validates COMPLETENESS
(every element covered; replicated copies must agree) — so a checkpoint
written by an 8-device (4, 2) mesh restores onto 1 device, 2 hosts, or any
other mesh shape (elastic re-scale), with the new sharding applied at
``device_put`` time against the caller's templates.

Crash atomicity: all payload is written into ``<final>.tmp``, each file is
fsync'd, the manifest is written last (also fsync'd, then the directory),
and the tmp dir is atomically renamed into place. A crash at ANY point
before the rename leaves only a ``.tmp`` directory that ``steps()`` never
lists; a torn final directory (manual tampering, partial copy) is rejected
by ``_valid`` (file sizes + slice-key sets checked against the manifest)
and ``latest_step`` falls back to the previous checkpoint. The fault sites
``ckpt_mid_write`` / ``ckpt_pre_commit`` (``runtime.fault_injection``) let
tests SIGKILL the writer at exactly those points.

Donation safety (the copy-before-donate contract): the train loop donates
the whole TrainState into every jitted step, so ``shard_snapshot`` copies
every leaf ON DEVICE first — synchronously, before the caller dispatches
the next step — and the background writer thread reads host views of those
throwaway copies only. The device->host DMA and the npz write both happen
on the writer thread; only the device-side copy is on the critical path.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from dataclasses import dataclass
from typing import Optional

import jax
import numpy as np

from repro.runtime.fault_injection import maybe_fault
from repro.utils.tree import flatten, unflatten

MANIFEST = "manifest.json"
FORMAT_VERSION = 2


def _ckpt_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:010d}")


def _shard_file(process_index: int) -> str:
    return f"shards.{process_index:05d}.npz"


# ------------------------------------------------------------------ snapshot
@dataclass
class ShardSlice:
    """One process-addressable slice of one leaf. ``data`` may be a
    single-device jax.Array (host transfer deferred to the writer thread)
    or a numpy array."""
    path: str
    offset: tuple                # global start index per dim
    shape: tuple                 # slice shape
    global_shape: tuple
    dtype: str
    data: object

    def key(self) -> str:
        return f"{self.path}@{'x'.join(map(str, self.offset))}"


def host_snapshot(state: dict) -> dict:
    """Synchronous device->host copy of a pytree (global arrays gathered).
    Kept for callers that want a plain numpy tree; the checkpoint writer
    itself uses :func:`shard_snapshot` (slice-sized host buffers)."""
    import jax.numpy as jnp
    flat = flatten(state)
    out = {}
    for k, v in flat.items():
        if isinstance(v, jax.Array):
            v = jnp.array(v)    # fresh buffer; the host view caches here
        out[k] = np.asarray(jax.device_get(v))
    return unflatten(out)


def shard_snapshot(state) -> list:
    """-> list[ShardSlice]: each leaf's unique addressable slices, backed by
    fresh DEVICE-SIDE copies.

    The device copy is load-bearing twice over: (a) the caller is about to
    donate the original state into the next step, so any async reader must
    not touch it; (b) on the CPU backend a host view of a jax.Array is
    ZERO-COPY and gets cached on the array, pinning its buffer with an
    external reference — which would silently disable donation for the rest
    of the run. Copying device-side first makes every later host view alias
    the throwaway copy. Replicated shards (several devices holding the same
    slice) are deduped by offset — each process writes each unique slice
    once."""
    import jax.numpy as jnp
    slices = []
    for path, leaf in flatten(state).items():
        if isinstance(leaf, jax.Array):
            copy = jnp.array(leaf, copy=True)   # sharding-preserving copy
            seen = set()
            for shard in copy.addressable_shards:
                off = tuple(int(s.start or 0) for s in shard.index)
                if off in seen:
                    continue
                seen.add(off)
                slices.append(ShardSlice(
                    path, off, tuple(shard.data.shape), tuple(leaf.shape),
                    str(leaf.dtype), shard.data))
        else:
            arr = np.asarray(leaf)
            slices.append(ShardSlice(path, (0,) * arr.ndim, tuple(arr.shape),
                                     tuple(arr.shape), str(arr.dtype), arr))
    return slices


# ---------------------------------------------------------------------- save
def _fsync_write(fp: str, write_fn) -> int:
    with open(fp, "wb") as f:
        write_fn(f)
        f.flush()
        os.fsync(f.fileno())
    return os.path.getsize(fp)


def _fsync_dir(d: str) -> None:
    fd = os.open(d, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def write_shard_file(tmp: str, process_index: int, slices: list):
    """Write ONE process's payload file into the staging dir.
    -> (fname, file_info, arrays_meta): the manifest fragments this
    process contributes. Multi-host saves call this once per process;
    :func:`commit` (process 0, after a barrier) unions the fragments."""
    fname = _shard_file(process_index)
    entries, arrays, arrays_meta = {}, {}, {}
    for s in slices:
        arr = np.ascontiguousarray(np.asarray(s.data))
        key = s.key()
        arrays[key] = arr
        entries[key] = {
            "path": s.path, "offset": list(s.offset),
            "shape": list(arr.shape),
            "crc": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
        }
        arrays_meta[s.path] = {"shape": list(s.global_shape),
                               "dtype": s.dtype}
    nbytes = _fsync_write(os.path.join(tmp, fname),
                          lambda f: np.savez(f, **arrays))
    maybe_fault("ckpt_mid_write")   # payload on disk, manifest NOT
    return fname, {"bytes": nbytes, "entries": entries}, arrays_meta


def commit(root: str, step: int, tmp: str, files: dict, arrays: dict,
           meta: Optional[dict] = None, keep: int = 3,
           process_count: int = 1) -> str:
    """Write the manifest over the staged payload files and atomically
    rename the staging dir into place. ``files``/``arrays`` are the unioned
    fragments from every process's :func:`write_shard_file`."""
    manifest = {
        "format": FORMAT_VERSION, "step": step, "meta": meta or {},
        "process_count": process_count,
        "arrays": arrays, "files": files,
    }
    _fsync_write(os.path.join(tmp, MANIFEST),
                 lambda f: f.write(json.dumps(manifest).encode()))
    _fsync_dir(tmp)

    maybe_fault("ckpt_pre_commit")  # everything written, NOT renamed

    final = _ckpt_dir(root, step)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _fsync_dir(root)
    _gc(root, keep)
    return final


def stage_dir(root: str, step: int, fresh: bool = True) -> str:
    """Create (or reuse) the staging dir a save writes into before commit."""
    os.makedirs(root, exist_ok=True)
    tmp = _ckpt_dir(root, step) + ".tmp"
    if fresh and os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    return tmp


def save(root: str, step: int, state, keep: int = 3,
         meta: Optional[dict] = None, process_index: int = 0,
         process_count: int = 1) -> str:
    """Atomically persist a pytree (or a precomputed ``shard_snapshot``
    list); returns the committed checkpoint path.

    ``meta`` is an arbitrary json-able dict stored in the manifest — the
    RunState packer puts the noise-mechanism state, the privacy ledger and
    the pipeline cursor there. On a multi-host pod every process runs
    ``write_shard_file`` for its addressable slices and process 0 runs
    ``commit`` after a barrier; this single-process entry point does both,
    through the same code path the tests drive piecewise."""
    slices = state if isinstance(state, list) else shard_snapshot(state)
    tmp = stage_dir(root, step, fresh=(process_index == 0))
    fname, finfo, arrays = write_shard_file(tmp, process_index, slices)
    return commit(root, step, tmp, {fname: finfo}, arrays, meta, keep,
                  process_count)


# ----------------------------------------------------------------- discovery
def steps(root: str):
    if not os.path.isdir(root):
        return []
    out = []
    for d in os.listdir(root):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                out.append(int(d[5:]))
            except ValueError:
                pass
    return sorted(out)


def _manifest(root: str, step: int) -> dict:
    with open(os.path.join(_ckpt_dir(root, step), MANIFEST)) as f:
        manifest = json.load(f)
    if manifest.get("format") != FORMAT_VERSION:
        raise IOError(
            f"checkpoint format {manifest.get('format')!r} at step {step}; "
            f"this build reads format {FORMAT_VERSION}")
    return manifest


def _valid(root: str, step: int) -> bool:
    """Cheap structural validation: manifest parses, every payload file
    exists at its recorded byte size, and its npz members match the
    manifest's slice index exactly. (Content CRCs are verified at restore —
    a full read per candidate would make ``latest_step`` O(checkpoint)
    instead of O(metadata).)"""
    d = _ckpt_dir(root, step)
    try:
        manifest = _manifest(root, step)
        files = manifest["files"]
        if not files:
            return False
        for fname, info in files.items():
            fp = os.path.join(d, fname)
            if not os.path.isfile(fp) or os.path.getsize(fp) != info["bytes"]:
                return False
            with np.load(fp) as z:
                if set(z.files) != set(info["entries"]):
                    return False
        return True
    except Exception:
        return False


def latest_step(root: str):
    """Newest checkpoint that passes validation (torn writes skipped)."""
    for s in reversed(steps(root)):
        if _valid(root, s):
            return s
    return None


# ------------------------------------------------------------------- restore
def restore(root: str, step=None, template=None, shardings=None):
    """Load a checkpoint -> (state, step, meta).

    Re-assembles every leaf's GLOBAL array from the saved slices, whatever
    topology wrote them: each slice is CRC-checked, duplicate offsets
    (replicated shards, possibly from different processes) must agree, and
    coverage is validated element-wise — a missing process file or a
    dropped slice raises instead of silently restoring zeros.

    ``template`` (pytree) enforces structure and dtypes for the keys it
    names; checkpoint keys OUTSIDE the template (e.g. a future mechanism's
    state arrays) pass through as numpy. ``shardings`` (pytree of
    jax.sharding or a single sharding) re-shards onto the CURRENT mesh —
    elastic restore onto a different topology than the one that saved."""
    step = latest_step(root) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no valid checkpoint under {root}")
    d = _ckpt_dir(root, step)
    manifest = _manifest(root, step)

    out, coverage, slice_crcs = {}, {}, {}
    for path, info in manifest["arrays"].items():
        out[path] = np.zeros(tuple(info["shape"]), dtype=info["dtype"])
        coverage[path] = np.zeros(tuple(info["shape"]), dtype=bool)
    for fname, finfo in manifest["files"].items():
        with np.load(os.path.join(d, fname)) as z:
            for key, e in finfo["entries"].items():
                arr = z[key]
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes()) \
                    & 0xFFFFFFFF
                if crc != e["crc"]:
                    raise IOError(f"checksum mismatch for {key} in {fname} "
                                  f"at step {step}")
                path, off = e["path"], tuple(e["offset"])
                prev = slice_crcs.setdefault((path, off), crc)
                if prev != crc:
                    raise IOError(
                        f"replicated slice disagreement for {path} at "
                        f"offset {off} (step {step})")
                region = tuple(slice(o, o + n)
                               for o, n in zip(off, arr.shape)) or ...
                out[path][region] = arr
                coverage[path][region] = True
    holes = [p for p, c in coverage.items() if not c.all()]
    if holes:
        raise IOError(
            f"incomplete shard coverage at step {step} for {sorted(holes)} "
            "(missing process file or dropped slice)")

    state = out
    if template is not None:
        tflat = flatten(template)
        missing = set(tflat) - set(state)
        if missing:
            raise IOError(f"checkpoint at step {step} lacks template keys "
                          f"{sorted(missing)}")
        state = {k: (state[k].astype(tflat[k].dtype) if k in tflat
                     else state[k]) for k in state}
    if shardings is not None:
        sflat = flatten(shardings) if isinstance(shardings, dict) else None
        state = {k: jax.device_put(v, sflat[k] if sflat else shardings)
                 for k, v in state.items()}
    return unflatten(state), manifest["step"], manifest.get("meta", {})


def _gc(root: str, keep: int):
    all_steps = steps(root)
    for s in all_steps[:-keep] if keep > 0 else []:
        shutil.rmtree(_ckpt_dir(root, s), ignore_errors=True)
