"""Fault-tolerant checkpointing.

Design (multi-pod): every parameter is saved as its GLOBAL array under its
tree path — checkpoints are sharding-agnostic, so a restart may load onto a
different mesh shape (elastic re-scale) and simply applies the new sharding
at restore (device_put against the template). Writes are atomic
(tmp-dir + rename); a manifest records step, keys, sizes and a checksum per
array so a torn write is detected and the previous checkpoint is used.
On a real multi-host pod each host would write its addressable shards
(process-sliced npz) with the same manifest/rename protocol; on this
single-process container the global save exercises the same code path.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib

import jax
import numpy as np

from repro.utils.tree import flatten, unflatten

MANIFEST = "manifest.json"


def _ckpt_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:010d}")


def host_snapshot(state: dict) -> dict:
    """Synchronous device->host copy of a pytree (global arrays gathered).

    The copy-before-donate contract: the train loop donates the whole
    TrainState into every jitted step, so any ASYNC reader (the checkpoint
    writer thread) must work from a host copy taken BEFORE the next step is
    dispatched — reading a donated jax.Array raises (or worse, on a runtime
    without the guard, reads reused memory). Blocks until the values are
    ready, which also bounds how far the loop can run ahead of the
    checkpoint cadence.

    The device-side copy is load-bearing: on the CPU backend a host view of
    a jax.Array is ZERO-COPY and gets CACHED on the array, pinning its
    buffer with an external reference for the array's remaining lifetime —
    the runtime then (correctly) refuses to donate it, silently costing a
    full state copy inside every subsequent step. Copying on device first
    makes the host view alias the throwaway copy instead; the original
    state stays donation-clean."""
    import jax.numpy as jnp
    flat = flatten(state)
    out = {}
    for k, v in flat.items():
        if isinstance(v, jax.Array):
            v = jnp.array(v)    # fresh buffer; the host view caches here
        out[k] = np.asarray(jax.device_get(v))
    return unflatten(out)


def save(root: str, step: int, state: dict, keep: int = 3) -> str:
    """Atomically persist a pytree; returns the checkpoint path."""
    os.makedirs(root, exist_ok=True)
    final = _ckpt_dir(root, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = flatten(state)
    manifest = {"step": step, "arrays": {}}
    arrays = {}
    for path, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        arrays[path] = arr
        manifest["arrays"][path] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc": zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF,
        }
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k: v for k, v in arrays.items()})
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(root, keep)
    return final


def steps(root: str):
    if not os.path.isdir(root):
        return []
    out = []
    for d in os.listdir(root):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                out.append(int(d[5:]))
            except ValueError:
                pass
    return sorted(out)


def _valid(root: str, step: int) -> bool:
    d = _ckpt_dir(root, step)
    mf = os.path.join(d, MANIFEST)
    if not (os.path.isfile(mf) and os.path.isfile(os.path.join(d, "arrays.npz"))):
        return False
    try:
        with open(mf) as f:
            manifest = json.load(f)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            keys = set(z.files)
        return set(manifest["arrays"]) == keys
    except Exception:
        return False


def latest_step(root: str):
    """Newest checkpoint that passes validation (torn writes skipped)."""
    for s in reversed(steps(root)):
        if _valid(root, s):
            return s
    return None


def restore(root: str, step=None, template=None, shardings=None):
    """Load a checkpoint. template (pytree) enforces structure and dtypes;
    shardings (pytree of jax.sharding) re-shards onto the CURRENT mesh —
    elastic restore onto a different topology than the one that saved."""
    step = latest_step(root) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no valid checkpoint under {root}")
    d = _ckpt_dir(root, step)
    with open(os.path.join(d, MANIFEST)) as f:
        manifest = json.load(f)
    with np.load(os.path.join(d, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    for k, meta in manifest["arrays"].items():
        crc = zlib.crc32(np.ascontiguousarray(flat[k]).tobytes()) & 0xFFFFFFFF
        if crc != meta["crc"]:
            raise IOError(f"checksum mismatch for {k} at step {step}")
    state = unflatten(flat)
    if template is not None:
        tflat = flatten(template)
        assert set(tflat) == set(flat), "checkpoint/template structure mismatch"
        state = unflatten({k: np.asarray(flat[k]).astype(tflat[k].dtype)
                           for k in flat})
    if shardings is not None:
        sflat = flatten(shardings) if isinstance(shardings, dict) else None
        state = unflatten({
            k: jax.device_put(v, sflat[k] if sflat else shardings)
            for k, v in flatten(state).items()})
    return state, manifest["step"]


def _gc(root: str, keep: int):
    all_steps = steps(root)
    for s in all_steps[:-keep] if keep > 0 else []:
        shutil.rmtree(_ckpt_dir(root, s), ignore_errors=True)
