"""whisper-small [audio] — arXiv:2212.04356. 12L enc + 12L dec, d=768,
12H (MHA), d_ff=3072, vocab=51865, LayerNorm+GELU, conv frontend STUBBED
(precomputed frame embeddings, frame_dim=80-mel x stride stub = 768)."""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


@register
def whisper_small() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="encdec", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072, vocab=51865,
        encoder_layers=12, decoder_len=448, frame_dim=768,
        norm="layernorm", act="gelu",
        dtype="bfloat16", param_dtype="bfloat16", remat=True, attn_chunk=512)
