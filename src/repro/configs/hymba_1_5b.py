"""hymba-1.5b [hybrid] — arXiv:2411.13676. 32L, d=1600, 25H GQA kv=5 (hd 64)
parallel attn+mamba heads, d_ff=5504, ssm_state=16, vocab=32001, SWA + 3
global-attention layers, 128 meta tokens."""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


@register
def hymba_1_5b() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
        n_heads=25, n_kv_heads=5, head_dim=64, d_ff=5504, vocab=32001,
        ssm_heads=25, ssm_state=16, window=1024, full_attn_layers=(0, 15, 31),
        meta_tokens=128, rope_theta=10000.0, norm="rmsnorm", act="swiglu",
        dtype="bfloat16", param_dtype="bfloat16", remat=True, attn_chunk=512)
