"""qwen2-1.5b [dense] — arXiv:2407.10671. 28L, d=1536, 12H GQA kv=2,
d_ff=8960, vocab=151936, QKV bias."""
from repro.configs.base import ModelConfig
from repro.configs.registry import register, register_policy
from repro.core.policy import ParamGroup, PrivacyPolicy


@register
def qwen2_1_5b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", family="dense", n_layers=28, d_model=1536,
        n_heads=12, n_kv_heads=2, head_dim=128, d_ff=8960, vocab=151936,
        qkv_bias=True, rope_theta=1000000.0, norm="rmsnorm", act="swiglu",
        dtype="bfloat16", param_dtype="bfloat16", remat=True, attn_chunk=512)


@register_policy("qwen2-1.5b")
def qwen2_1_5b_policy() -> PrivacyPolicy:
    """Embedding + LM head (the 151936-row vocab tables, whose per-sample
    gradients are T-sparse and systematically smaller-normed than the dense
    trunk's) clipped group-wise with their own R; transformer blocks form
    the flat pool."""
    return PrivacyPolicy(groups=(
        ParamGroup("vocab", r"(embed|head)/.*", R=0.5, scope="group"),
        ParamGroup("trunk", ".*", R=1.0, scope="flat"),
    ), mode="bk-mixopt")
