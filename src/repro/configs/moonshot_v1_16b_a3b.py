"""moonshot-v1-16b-a3b [moe] — hf:moonshotai/Moonlight-16B-A3B (kimi).
48L (assignment spec), d=2048, 16H kv=16, expert d_ff=1408, 64 routed top-6
+ 2 shared, vocab=163840."""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


@register
def moonshot_v1_16b_a3b() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
        n_heads=16, n_kv_heads=16, head_dim=128, d_ff=11264, vocab=163840,
        n_experts=64, top_k=6, n_shared=2, moe_d_ff=1408, first_k_dense=1, capacity_factor=1.25,
        renorm_topk=True, rope_theta=50000.0, norm="rmsnorm", act="swiglu",
        dtype="bfloat16", param_dtype="bfloat16", remat=True, attn_chunk=512)
