"""deepseek-moe-16b [moe] — arXiv:2401.06066. 28L, d=2048, 16H kv=16,
expert d_ff=1408, 64 routed top-6 + 2 shared, first layer dense,
vocab=102400."""
from repro.configs.base import ModelConfig
from repro.configs.registry import register, register_policy
from repro.core.policy import ParamGroup, PrivacyPolicy


@register
def deepseek_moe_16b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe", n_layers=28, d_model=2048,
        n_heads=16, n_kv_heads=16, head_dim=128, d_ff=10944, vocab=102400,
        n_experts=64, top_k=6, n_shared=2, moe_d_ff=1408, first_k_dense=1, capacity_factor=1.25,
        renorm_topk=False, rope_theta=10000.0, norm="rmsnorm", act="swiglu",
        dtype="bfloat16", param_dtype="bfloat16", remat=True, attn_chunk=512)


@register_policy("deepseek-moe-16b")
def deepseek_moe_16b_policy() -> PrivacyPolicy:
    """Group-wise clipping split along the model's natural axes: routed
    expert weights (each sample touches top-k of 64, so per-sample expert
    gradients are sparse and small-normed) get their own clipping unit and
    threshold, the router its own (tiny but gradient-sensitive), everything
    else (attention / shared-FFN / embeddings) forms the dense trunk unit.
    Sensitivity composes as sqrt(R_experts^2 + R_router^2 + R_dense^2)."""
    return PrivacyPolicy(groups=(
        ParamGroup("experts", r".*/experts/.*", R=0.5, scope="group"),
        ParamGroup("router", r".*/router/.*", R=0.25, scope="group"),
        ParamGroup("dense", ".*", R=1.0, scope="group"),
    ), mode="bk-mixopt")
