"""qwen2.5-3b [dense] — hf:Qwen/Qwen2.5 family. 36L, d=2048, 16H GQA kv=2,
d_ff=11008, vocab=151936, QKV bias."""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


@register
def qwen2_5_3b() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b", family="dense", n_layers=36, d_model=2048,
        n_heads=16, n_kv_heads=2, head_dim=128, d_ff=11008, vocab=151936,
        qkv_bias=True, rope_theta=1000000.0, norm="rmsnorm", act="swiglu",
        dtype="bfloat16", param_dtype="bfloat16", remat=True, attn_chunk=512)
