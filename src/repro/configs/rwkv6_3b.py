"""rwkv6-3b [ssm] — arXiv:2404.05892 (Finch). 32L, d=2560 (40 heads x 64),
attention-free, d_ff=8960, vocab=65536, data-dependent decay."""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


@register
def rwkv6_3b() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", family="ssm", n_layers=32, d_model=2560,
        n_heads=40, n_kv_heads=40, head_dim=64, d_ff=8960, vocab=65536,
        norm="layernorm", act="relu_sq",
        dtype="bfloat16", param_dtype="bfloat16", remat=True)
