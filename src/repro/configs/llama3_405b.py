"""llama3-405b [dense] — arXiv:2407.21783. 126L, d=16384, 128H GQA kv=8,
d_ff=53248, vocab=128256."""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


@register
def llama3_405b() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b", family="dense", n_layers=126, d_model=16384,
        n_heads=128, n_kv_heads=8, head_dim=128, d_ff=53248, vocab=128256,
        rope_theta=500000.0, norm="rmsnorm", act="swiglu",
        dtype="bfloat16", param_dtype="bfloat16", remat=True, attn_chunk=512)
