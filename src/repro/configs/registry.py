"""Architecture registry: ``--arch <id>`` -> (ModelConfig, model class).

Full configs match the assignment table exactly; ``smoke()`` returns a
reduced same-family config for CPU tests. ``build(cfg)`` instantiates the
right model class for the family.

Arch modules may also register a named :class:`PrivacyPolicy` preset
(``register_policy``) — the per-parameter-group DP recipe for that model
(e.g. deepseek-moe-16b clips expert weights group-wise, separately from the
dense trunk). ``get_policy(name, **overrides)`` materializes it with
engine-level fields (mode / sigma / noise / use_kernels) replaced.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable

from repro.configs.base import ModelConfig

_REGISTRY: dict = {}
_POLICIES: dict = {}


def register(fn: Callable[[], ModelConfig]):
    cfg = fn()
    _REGISTRY[cfg.name] = fn
    return fn


def register_policy(name: str):
    """Decorator: register ``fn() -> PrivacyPolicy`` as preset ``name``."""
    def deco(fn):
        _POLICIES[name] = fn
        return fn
    return deco


def get_policy(name: str, **overrides):
    """Named PrivacyPolicy preset, with engine-level field overrides
    (mode=..., sigma=..., noise=..., use_kernels=...)."""
    try:
        policy = _POLICIES[name]()
    except KeyError:
        raise KeyError(f"no policy preset for {name!r}; known: "
                       f"{sorted(_POLICIES)}")
    return dataclasses.replace(policy, **overrides) if overrides else policy


def has_policy(name: str) -> bool:
    return name in _POLICIES


def list_policies():
    return sorted(_POLICIES)


def get_config(name: str) -> ModelConfig:
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")


def list_archs():
    return sorted(_REGISTRY)


def build(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models.transformer import TransformerLM
        return TransformerLM(cfg)
    if cfg.family == "ssm":
        from repro.models.rwkv6 import Rwkv6LM
        return Rwkv6LM(cfg)
    if cfg.family == "hybrid":
        from repro.models.hymba import HymbaLM
        return HymbaLM(cfg)
    if cfg.family == "encdec":
        from repro.models.whisper import WhisperLM
        return WhisperLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    cfg = get_config(name)
    kw = dict(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
              d_ff=48, vocab=64, max_t=64)
    if cfg.family == "moe":
        kw.update(n_experts=4, top_k=2, moe_d_ff=16,
                  first_k_dense=min(1, cfg.first_k_dense),
                  n_shared=min(1, cfg.n_shared))
    if cfg.family == "ssm":
        kw.update(d_model=128, n_heads=2, head_dim=64)  # rwkv head size 64
    if cfg.family == "hybrid":
        kw.update(n_layers=5, ssm_heads=4, ssm_state=4, window=8,
                  full_attn_layers=(0, 2, 4), meta_tokens=4)
    if cfg.family == "encdec":
        kw.update(encoder_layers=2, decoder_len=16, frame_dim=24,
                  n_kv_heads=4)
    if cfg.family == "vlm":
        kw.update(patch_tokens=4, vit_dim=16)
    return cfg.with_(**kw)


# import arch modules so registration runs
for _m in ("whisper_small", "llama3_405b", "qwen2_1_5b", "qwen3_14b",
           "qwen2_5_3b", "moonshot_v1_16b_a3b", "deepseek_moe_16b",
           "internvl2_26b", "rwkv6_3b", "hymba_1_5b"):
    importlib.import_module(f"repro.configs.{_m}")
