"""internvl2-26b [vlm] — arXiv:2404.16821. InternLM2-20B backbone: 48L,
d=6144, 48H GQA kv=8, d_ff=16384, vocab=92553. InternViT frontend is a STUB
(precomputed patch embeddings, vit_dim=3200, projected by a tapped linear)."""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


@register
def internvl2_26b() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", family="vlm", n_layers=48, d_model=6144,
        n_heads=48, n_kv_heads=8, head_dim=128, d_ff=16384, vocab=92553,
        patch_tokens=1024, vit_dim=3200,
        rope_theta=1000000.0, norm="rmsnorm", act="swiglu",
        dtype="bfloat16", param_dtype="bfloat16", remat=True, attn_chunk=512)
