"""qwen3-14b [dense] — hf:Qwen/Qwen3 family. 40L, d=5120, 40H GQA kv=8,
d_ff=17408, vocab=151936, qk_norm."""
from repro.configs.base import ModelConfig
from repro.configs.registry import register


@register
def qwen3_14b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b", family="dense", n_layers=40, d_model=5120,
        n_heads=40, n_kv_heads=8, head_dim=128, d_ff=17408, vocab=151936,
        qk_norm=True, rope_theta=1000000.0, norm="rmsnorm", act="swiglu",
        dtype="bfloat16", param_dtype="bfloat16", remat=True, attn_chunk=512)
