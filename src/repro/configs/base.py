"""Model / training / DP configuration dataclasses."""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 2
    d_model: int = 64
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 128
    vocab: int = 256
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "swiglu"          # swiglu | gelu
    rope_theta: float = 10000.0
    max_t: int = 4096            # rope table length (>= longest seq incl. cache)
    tie_embeddings: bool = False
    attn_chunk: int = 0          # q-chunked attention block (0 = full)
    seq_shard_attn: bool = False # context-parallel attention (q seq over 'model')
    seq_parallel: bool = False   # Megatron-SP: residual stream seq-sharded over 'model'
    remat: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0       # leading dense-FFN layers (DeepSeekMoE style)
    capacity_factor: float = 2.0
    renorm_topk: bool = True

    # SSM / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_chunk: int = 32          # wkv/ssm chunked-scan length
    window: int = 0              # sliding window for local attn layers
    full_attn_layers: tuple = () # hybrid: layer indices with global attention
    meta_tokens: int = 0         # Hymba learnable prefix tokens

    # enc-dec (whisper)
    encoder_layers: int = 0
    decoder_len: int = 448
    frame_dim: int = 0           # stub frontend embedding dim (0 -> d_model)

    # vlm
    patch_tokens: int = 0        # stub patch count for train shapes
    vit_dim: int = 0             # stub ViT output dim

    dtype: str = "float32"       # activations/compute
    param_dtype: str = "float32"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 8
    microbatch: int = 0          # physical batch per step (0 = global)
    seq_len: int = 128
    steps: int = 10
    lr: float = 1e-3
    lr_schedule: str = "cosine"
    optimizer: str = "adamw"
    weight_decay: float = 0.0
    warmup: int = 0
    # DP-FTRL (optimizer="ftrl"): momentum over noisy gradient prefixes,
    # epoch restarts every N steps (0 = never; also drives the tree-noise
    # mechanism's restarts), and Honaker tree completion at each restart
    ftrl_momentum: float = 0.0
    restart_every: int = 0
    tree_completion: bool = False
    seed: int = 0
    # mesh for the real train driver: (data, model) axis sizes; data=0 means
    # "all devices / model" (launch.mesh.make_train_mesh)
    mesh_data: int = 0
    mesh_model: int = 1
    # loss/timing log + device->host flush period in steps: the loop keeps
    # losses on device and drains them every log_every steps (and at exit),
    # so no step blocks on a host sync
    log_every: int = 10
    checkpoint_every: int = 0
    checkpoint_dir: str = ""
    keep_checkpoints: int = 3
    # PrivacyPolicy preset name (configs.registry.get_policy):
    #   ""     — flat single-group DP from the DPConfig alone
    #   "auto" — use the arch's registered preset when one exists
    #   other  — a specific registered preset
    policy: str = "auto"
    # measured kernel autotune at startup (kernels.dispatch.autotune):
    #   "auto" — on for real accelerators, off on CPU (interpret mode)
    #   "on" / "off"
    autotune: str = "auto"
    # tape residency override (core.tape.TAPE_POLICIES): "" keeps whatever
    # the DPConfig / policy preset configured; tape_chunks 0 likewise
    tape: str = ""
    tape_chunks: int = 0
    # clipping-scope override (core.policy.SCOPES): re-scope every trainable
    # group of the DPConfig/preset via policy.with_scope — "layer" makes
    # each param path its own clip unit and streams the BK backward
    # (one pass, nothing book-kept); "" keeps the preset's scopes
    clipping_scope: str = ""


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
