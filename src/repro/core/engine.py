"""PrivacyEngine facade: one entry point for all DP implementations.

Mirrors the paper's Sec. 4 usage — choose a ``clipping_mode`` and get back a
drop-in gradient function with the same signature as non-private training:

    engine = PrivacyEngine(model.apply, DPConfig(mode="bk-mixopt", sigma=...))
    grads, aux = engine.grad(params, batch, rng)

Modes: 'nonprivate' | 'tfprivacy' | 'opacus' | 'fastgradclip' | 'ghostclip'
     | 'bk' | 'bk-mixghost' | 'bk-mixopt'
"""
from __future__ import annotations

from dataclasses import replace
from typing import Callable

from repro.core import baselines
from repro.core.accounting import budget_for
from repro.core.bk import BK_MODES, DPConfig, bk_private_grad, plan_report

_BASELINES = {
    "nonprivate": baselines.nonprivate_grad,
    "tfprivacy": baselines.tfprivacy_grad,
    "opacus": baselines.opacus_grad,
    "fastgradclip": baselines.fastgradclip_grad,
    "ghostclip": baselines.ghostclip_grad,
}

ALL_MODES = tuple(_BASELINES) + BK_MODES


def make_grad_fn(apply_fn: Callable, cfg: DPConfig) -> Callable:
    """-> fn(params, batch, rng) -> (grads, aux). Pure; jit/pjit it freely."""
    if cfg.mode in BK_MODES:
        return lambda params, batch, rng: bk_private_grad(apply_fn, params, batch, rng, cfg)
    if cfg.mode in _BASELINES:
        fn = _BASELINES[cfg.mode]
        return lambda params, batch, rng: fn(apply_fn, params, batch, rng, cfg)
    raise ValueError(f"unknown mode {cfg.mode!r}; options: {ALL_MODES}")


class PrivacyEngine:
    """Stateful convenience wrapper (accounting + grad fn)."""

    def __init__(self, apply_fn: Callable, cfg: DPConfig,
                 batch_size: int = 0, dataset_size: int = 0,
                 epochs: float = 0.0, target_epsilon: float = 0.0,
                 delta: float = 1e-5):
        if target_epsilon > 0.0:
            budget = budget_for(target_epsilon, delta, batch_size,
                                dataset_size, epochs)
            cfg = replace(cfg, sigma=budget.sigma)
            self.budget = budget
        else:
            self.budget = None
        self.cfg = cfg
        self.apply_fn = apply_fn
        self.grad = make_grad_fn(apply_fn, cfg)

    def kernel_report(self, params, batch) -> dict:
        """Per-tap kernel dispatch plans (impl/method/blocks) for this model
        and batch shape — one free eval_shape pass, no compute. Lets users
        see (and log) what ``use_kernels`` will actually run before training.
        """
        return plan_report(self.apply_fn, params, batch, self.cfg)
