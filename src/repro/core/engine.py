"""PrivacyEngine facade: one entry point for all DP implementations.

Mirrors the paper's Sec. 4 usage — choose a ``clipping_mode`` and get back a
drop-in gradient function with the same signature as non-private training:

    engine = PrivacyEngine(model.apply, DPConfig(mode="bk-mixopt", sigma=...))
    grads, aux = engine.grad(params, batch, rng)

or hand it a :class:`repro.core.policy.PrivacyPolicy` for per-parameter-group
DP (group-wise clipping, frozen groups, pluggable noise):

    policy = PrivacyPolicy(groups=(
        ParamGroup("adapters", r".*lora.*", R=1.0, scope="group"),
        ParamGroup("base", ".*", trainable=False),
    ), mode="bk", sigma=0.5)
    engine = PrivacyEngine(model.apply, policy)

Heterogeneous noise rides the same policy (``ParamGroup.sigma_scale``), and
DP-FTRL training swaps ``noise="tree"`` in (with epoch restarts /
completion) — pass the step index to ``engine.grad(..., step)`` for any
stateful mechanism.

Modes: 'nonprivate' | 'tfprivacy' | 'opacus' | 'fastgradclip' | 'ghostclip'
     | 'bk' | 'bk-mixghost' | 'bk-mixopt'
"""
from __future__ import annotations

from dataclasses import replace
from typing import Callable

from repro.core import baselines
from repro.core.accounting import budget_for
from repro.core.bk import BK_MODES, DPConfig, bk_private_grad, plan_report
from repro.core.policy import ParamGroup, PrivacyPolicy, as_policy

_BASELINES = {
    "nonprivate": baselines.nonprivate_grad,
    "tfprivacy": baselines.tfprivacy_grad,
    "opacus": baselines.opacus_grad,
    "fastgradclip": baselines.fastgradclip_grad,
    "ghostclip": baselines.ghostclip_grad,
}

ALL_MODES = tuple(_BASELINES) + BK_MODES


def make_grad_fn(apply_fn: Callable, cfg, mesh=None, pspecs=None) -> Callable:
    """-> fn(params, batch, rng, step=None) -> (grads, aux). Pure; jit/pjit it
    freely (``step`` only matters to stateful noise mechanisms such as tree
    aggregation; it may be a traced scalar). ``cfg`` is a DPConfig or a
    PrivacyPolicy. ``mesh``/``pspecs`` lower the pipeline batch-sharded with
    shard-local noise — EVERY mode's phase 4 honors them (BK modes
    additionally shard the book-keeping itself)."""
    policy = as_policy(cfg)
    if policy.mode in BK_MODES:
        return lambda params, batch, rng, step=None: bk_private_grad(
            apply_fn, params, batch, rng, policy, step, mesh=mesh,
            pspecs=pspecs)
    if policy.mode in _BASELINES:
        fn = _BASELINES[policy.mode]
        return lambda params, batch, rng, step=None: fn(
            apply_fn, params, batch, rng, policy, step, mesh=mesh,
            pspecs=pspecs)
    raise ValueError(f"unknown mode {policy.mode!r}; options: {ALL_MODES}")


class PrivacyEngine:
    """Stateful convenience wrapper (accounting + grad fn)."""

    def __init__(self, apply_fn: Callable, cfg,
                 batch_size: int = 0, dataset_size: int = 0,
                 epochs: float = 0.0, target_epsilon: float = 0.0,
                 delta: float = 1e-5):
        if target_epsilon > 0.0:
            budget = budget_for(target_epsilon, delta, batch_size,
                                dataset_size, epochs)
            cfg = replace(cfg, sigma=budget.sigma)
            self.budget = budget
        else:
            self.budget = None
        self.cfg = cfg
        self.policy = as_policy(cfg)
        self.apply_fn = apply_fn
        self.grad = make_grad_fn(apply_fn, cfg)

    def kernel_report(self, params, batch) -> dict:
        """Per-tap kernel dispatch plans (impl/method/blocks) for this model
        and batch shape — one free eval_shape pass, no compute. Lets users
        see (and log) what ``use_kernels`` will actually run before training.
        Frozen-group taps are absent (they do no norm/grad work at all).
        """
        return plan_report(self.apply_fn, params, batch, self.cfg)
