"""The tap mechanism: JAX-native book-keeping + ghost differentiation.

Every generalized-linear op computes ``s = f(a, W) + tap`` where ``tap`` is an
explicit all-zeros argument, and records its activation. The BK engine then
runs one ``jax.vjp`` **with respect to the taps only** — the cotangent of a
tap *is* the output gradient dL/ds of that layer, and because the weights are
not differentiated XLA never builds the non-private parameter-gradient matmul
(module 2b of the paper). This realizes the paper's "ghost differentiation"
and "book-keeping" tricks natively, without PyTorch's requires_grad/origin-
parameter machinery.

Key naming: ``<path>#<kind>[.s]`` where kind is one of
  mm   — matmul: record = activation a, layouts (B,T,d) / stacked (L,B,T,d)
  emb  — embedding lookup: record = int ids (B,T) / (L,B,T)
  moe  — gathered expert matmul: record = {'a': (B,E,C,d), 'mask': (B,E,C)}
and the ``.s`` suffix marks records stacked over a leading scan (layer) axis.

The parameter owned by a tapped op lives at ``<path>/w`` in the params tree.
All other parameter leaves (biases, norm scales, decay vectors, ...) are
handled by the per-sample-parameter (psp) route in the engine.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


class Tape:
    """Threads taps into generalized-linear outputs and collects activations.

    A Tape is created inside the traced function. ``taps=None`` runs the model
    untapped (standard training / shape-collection pass); the Tape still
    records ``tap_zeros`` (zeros_like of each tap site output) which under
    ``jax.eval_shape`` yields the tap structure for free.
    """

    def __init__(self, taps: Optional[dict] = None, collect: bool = True):
        self.taps = taps
        self.collect = collect
        self.acts: dict = {}
        self.tap_zeros: dict = {}
        self._prefix: list = []
        self._scan_sub = False       # set by subtape_run: keys are relative
                                     # to the enclosing scan scope

    @classmethod
    def null(cls) -> "Tape":
        """Inference tape: no taps, records nothing (keeps serving HLO free
        of dead tap-zero scan outputs)."""
        return cls(None, collect=False)

    # ------------------------------------------------------------------ scope
    class _Scope:
        def __init__(self, tape, name):
            self.tape, self.name = tape, name

        def __enter__(self):
            self.tape._prefix.append(self.name)

        def __exit__(self, *exc):
            self.tape._prefix.pop()

    def scope(self, name: str) -> "_Scope":
        return Tape._Scope(self, name)

    def key(self, name: str, kind: str) -> str:
        return "/".join(self._prefix + [name]) + "#" + kind

    # ------------------------------------------------------------------- taps
    def _apply_tap(self, key: str, s: jnp.ndarray) -> jnp.ndarray:
        self.tap_zeros[key] = jnp.zeros_like(s)
        # a key absent from a non-None taps dict is a frozen-group op (the
        # policy dropped it from differentiation): pass through untapped
        if self.taps is not None and key in self.taps:
            s = s + self.taps[key]
        return s

    def record(self, name: str, kind: str, s: jnp.ndarray, act) -> jnp.ndarray:
        """Generic tap site: returns s (+tap) and records the activation
        (in the active ``act_storage`` representation — compressing here,
        inside the scan body, is what keeps the stacked ys compact)."""
        if not self.collect:
            return s
        key = self.key(name, kind)
        if key in self.acts:
            raise ValueError(f"duplicate tap key {key!r}")
        s = self._apply_tap(key, s)
        store = _ACT_STORE[-1]
        if not isinstance(store, str):
            # per-tap resolver (scope-relative per-group overrides): sub-
            # Tapes inside scan bodies see only relative keys, so rebuild
            # the merged key from the enclosing scan-scope prefix
            full = key
            if self._scan_sub and _SCOPE_PREFIX:
                full = _SCOPE_PREFIX[-1] + key + ".s"
            store = store(full)
        if store != "native":
            act = store_record(act, store, _ACT_RNG[-1])
        self.acts[key] = act
        return s

    # --------------------------------------------------------- merging (scan)
    def subtaps(self, name: str) -> Optional[dict]:
        """Taps subtree for a scan scope, keys relativized. None if untapped.

        Also pushes the scope's absolute prefix onto the trace-scoped stack
        (popped by the paired :meth:`merge_stacked`) so sub-Tape records —
        which see only relative keys — can resolve their MERGED key for the
        per-tap activation-storage resolver."""
        prefix = "/".join(self._prefix + [name]) + "/"
        _SCOPE_PREFIX.append(prefix)
        if self.taps is None:
            return None
        out = {}
        for k, v in self.taps.items():
            if k.startswith(prefix):
                rel = k[len(prefix):]
                if rel.endswith(".s"):  # stacked marker lives on the merged key
                    rel = rel[:-2]
                out[rel] = v
        return out

    def merge_stacked(self, name: str, acts: dict, tap_zeros: dict) -> None:
        """Merge a scanned sub-tape's stacked outputs under ``name``.

        ``acts``/``tap_zeros`` are the stacked (leading layer axis) trees
        returned as scan ys; keys get prefixed and marked with ``.s``.
        Pops the scope prefix its paired :meth:`subtaps` pushed.
        """
        prefix = "/".join(self._prefix + [name]) + "/"
        if _SCOPE_PREFIX and _SCOPE_PREFIX[-1] == prefix:
            _SCOPE_PREFIX.pop()
        for k, v in acts.items():
            self.acts[prefix + k + ".s"] = v
        for k, v in tap_zeros.items():
            self.tap_zeros[prefix + k + ".s"] = v


def parse_key(key: str):
    """-> (param_path, kind, stacked)."""
    path, _, kindpart = key.rpartition("#")
    stacked = kindpart.endswith(".s")
    kind = kindpart[:-2] if stacked else kindpart
    return path, kind, stacked


def fix_scan_params(tree: dict, tapped: bool) -> dict:
    """Prepare stacked block params for lax.scan under the DP psp route.

    The engine broadcasts every non-ghost leaf to (B, L, ...); scan needs the
    layer axis leading. Ghost weights (leaf key 'w' of tapped ops — the layer
    library's convention) stay (L, ...). No-op when running untapped.
    """
    if not tapped:
        return tree
    from repro.utils.tree import flatten, unflatten  # local: avoid cycle

    flat = {}
    for path, leaf in flatten(tree).items():
        if not path.endswith("/w") and leaf.ndim >= 2:
            leaf = jnp.moveaxis(leaf, 0, 1)
        flat[path] = leaf
    return unflatten(flat)


# ------------------------------------------------------------ tape residency
# Storage policies for book-kept tap records (activations, held cotangents,
# the mixopt per-sample-grad cache) between BK phases 2 and 3:
# (activation storage is applied AT RECORD TIME — inside scan bodies, via
# the ``act_storage`` context — so the stacked native activation ys never
# materialize; post-hoc compression would briefly hold both copies at the
# scan boundary and save nothing at the peak)
#   native     keep the array as produced (bitwise-identical engine output)
#   bf16       hold a bfloat16 copy; fp32 norm/clip accumulation is preserved
#   int8       hold an int8 stochastic-rounding quantization (per-tensor
#              scale, runtime.compression.quantize) — unbiased, loosest parity
#   recompute  hold NOTHING; the cotangent is re-derived in phase 3 by a
#              second chunked backward sweep over the phase-1 linearization
#   auto       per-tap choice by the dispatch residency planner
#              (kernels.dispatch.tape_plan)
# Integer / bool leaves (embedding ids, MoE masks) are already compact and
# always pass through untouched.
TAPE_POLICIES = ("native", "bf16", "int8", "recompute", "auto")

# trace-time stacks for the activation-tape storage representation: models
# create sub-Tapes deep inside scan bodies (subtape_run) where keys are
# still scope-relative, so the ACTIVATION side of the residency policy is a
# trace-scoped setting — either a uniform store name, or a RESOLVER
# callable(full_key) -> store that the engine builds from the policy's
# per-group ``tape`` overrides (records inside scan bodies rebuild their
# merged key from the _SCOPE_PREFIX stack pushed by Tape.subtaps).
# ('recompute' keeps acts native — they ARE the standard tape.) int8 uses
# the pushed rng; inside a scan body it is a trace constant, so every layer
# reuses one rounding draw (documented; the held-cotangent side keys
# per-path).
_ACT_STORE: list = ["native"]
_ACT_RNG: list = [None]
_SCOPE_PREFIX: list = []


class act_storage:
    """Context manager scoping the activation-tape storage representation
    around a traced ``apply_fn`` call (engine-internal). ``store`` is a
    store name, or a callable(full_tap_key) -> store name for per-tap
    resolution (the callable must already map recompute/auto to native)."""

    def __init__(self, store, rng=None):
        if isinstance(store, str) and store in ("recompute", "auto"):
            store = "native"
        self.store = store
        self.rng = rng

    def __enter__(self):
        _ACT_STORE.append(self.store)
        _ACT_RNG.append(self.rng)

    def __exit__(self, *exc):
        _ACT_STORE.pop()
        _ACT_RNG.pop()


def store_record(x, policy: str, rng=None):
    """One tap record -> its held representation under a storage policy.

    ``recompute`` never reaches here — dropping the record is the caller's
    move (there is nothing to store). int8 needs ``rng`` for the stochastic
    rounding draw."""
    if policy in ("native", "recompute"):
        return x
    if isinstance(x, dict):          # moe record {'a': float, 'mask': ...}
        out = dict(x)
        out["a"] = store_record(x["a"], policy, rng)
        return out
    if not jnp.issubdtype(x.dtype, jnp.floating):
        return x                     # ids / masks: already compact
    if policy == "bf16":
        return x.astype(jnp.bfloat16)
    if policy == "int8":
        from repro.runtime.compression import quantize
        q, scale = quantize(x, rng)
        return {"q": q, "scale": scale}
    raise ValueError(f"unknown tape storage policy {policy!r}; options: "
                     f"{TAPE_POLICIES[:-1]}")


def load_record(stored, dtype=None):
    """Inverse of :func:`store_record`: -> an array in ``dtype`` (the
    record's native dtype) ready for the norm / weighted-grad consumers.
    Loads are elementwise (cast / dequant) so XLA fuses them into the
    consumer — the full-precision copy never materializes in HBM."""
    if isinstance(stored, dict):
        if "q" in stored:            # int8 (q, scale) pair
            from repro.runtime.compression import dequantize
            return dequantize(stored["q"], stored["scale"],
                              dtype or jnp.float32)
        out = dict(stored)
        out["a"] = load_record(stored["a"], dtype)
        return out
    if dtype is not None and stored.dtype != dtype and \
            jnp.issubdtype(stored.dtype, jnp.floating):
        return stored.astype(dtype)
    return stored


def subtape_run(block_fn, params_l, taps_l, *args, collect: bool = True):
    """Helper to run a block inside a scan body with its own sub-Tape.

    Returns (out, (acts, tap_zeros)) so the caller can stack them as scan ys
    and merge with :meth:`Tape.merge_stacked`. With ``collect=False`` the
    aux dicts are empty (inference: no dead tap-zero scan outputs).
    """
    tape = Tape(taps_l, collect=collect)
    tape._scan_sub = True
    out = block_fn(params_l, tape, *args)
    return out, (tape.acts, tape.tap_zeros)
