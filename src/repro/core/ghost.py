"""Ghost-norm / direct-norm / weighted-gradient math (pure-jnp reference).

Implements the paper's modules on book-kept tensors:

  module 3  (ghost norm):      ||g_i||_F^2 = < a_i a_i^T , ds_i ds_i^T >_F
  module 4  (direct norm):     instantiate g_i = a_i^T ds_i, take ||.||_F^2
  module 2b' (weighted grad):  G = a^T diag(C) ds   (the BK line 9 einsum)

Layouts (see core.tape):
  mm   a (B,T,d)  ds (B,T,p)      stacked: (L,B,T,d) / (L,B,T,p)
  emb  ids (B,T)  ds (B,T,d)      stacked: (L,B,T)   / (L,B,T,d)
  moe  {'a': (B,E,C,d), 'mask': (B,E,C)}  ds (B,E,C,p)   stacked: +L

All norm accumulation is float32. The fused Pallas kernels in repro.kernels
compute the same quantities without materializing the (T,T) Grams / (d,p)
per-sample grads in HBM; ``use_kernels`` in the engine switches the dispatch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def _f32(x):
    return x.astype(F32)


# Above this many elements for the would-be intermediate (Grams / per-sample
# grads), the norm is computed with a sequential lax.map over (layer, sample)
# so only ONE intermediate is live — the XLA analogue of the fused Pallas
# kernels, and what keeps full-BK book-keeping the only O(model)-sized state.
MAP_THRESHOLD = 1 << 24


def _norm4(a: jnp.ndarray, ds: jnp.ndarray):
    """Canonicalize mm records to (G, B, T, d) with G = stacked layers."""
    if a.ndim == 3:
        return a[None], ds[None]
    if a.ndim == 4:
        return a, ds
    raise ValueError(f"mm record must be 3D or 4D, got {a.shape}")


# =============================================================== matmul (mm)
def sq_norm_mm_ghost(a: jnp.ndarray, ds: jnp.ndarray) -> jnp.ndarray:
    """Ghost norm for s = a W. Returns per-sample squared norms (B,).

    < a_i a_i^T, ds_i ds_i^T >_F, cost 2BT^2(p+d), without forming g_i.
    Large records: per-(layer,sample) lax.map keeps one (T,T) Gram pair live.
    """
    a, ds = _norm4(a, ds)
    G, B, T, _ = a.shape
    # bf16 inputs feed the MXU directly with f32 accumulation — never cast
    # the (large, book-kept) inputs wholesale: XLA hoists such casts out of
    # the lax.map and materializes f32 copies of every tap.
    pe = dict(preferred_element_type=F32)
    if G * B * T * T <= MAP_THRESHOLD:
        ga = jnp.einsum("gbtd,gbsd->gbts", a, a, **pe)
        gg = jnp.einsum("gbtp,gbsp->gbts", ds, ds, **pe)
        return jnp.einsum("gbts,gbts->b", ga, gg, **pe)

    def one(args):
        ab, gb = args
        ga = jnp.einsum("td,sd->ts", ab, ab, **pe)
        gg = jnp.einsum("tp,sp->ts", gb, gb, **pe)
        return jnp.sum(ga * gg)

    n = jax.lax.map(one, (a.reshape((G * B,) + a.shape[2:]),
                          ds.reshape((G * B,) + ds.shape[2:])))
    return n.reshape(G, B).sum(0)


def sq_norm_mm_direct(a: jnp.ndarray, ds: jnp.ndarray) -> jnp.ndarray:
    """Per-sample-grad instantiation norm (Opacus module 4). Cost 2BTpd.
    Large records: per-(layer,sample) lax.map keeps one (d,p) grad live —
    removes Opacus's Bpd space term (mirrors kernels/grad_norm_direct)."""
    a, ds = _norm4(a, ds)
    G, B, _, d = a.shape
    p = ds.shape[-1]
    pe = dict(preferred_element_type=F32)
    if G * B * d * p <= MAP_THRESHOLD:
        g = jnp.einsum("gbtd,gbtp->gbdp", a, ds, **pe)
        return jnp.einsum("gbdp,gbdp->b", g, g)

    def one(args):
        ab, gb = args
        g = jnp.einsum("td,tp->dp", ab, gb, **pe)
        return jnp.sum(g * g)

    n = jax.lax.map(one, (a.reshape((G * B,) + a.shape[2:]),
                          ds.reshape((G * B,) + ds.shape[2:])))
    return n.reshape(G, B).sum(0)


def weighted_grad_mm(a: jnp.ndarray, C: jnp.ndarray, ds: jnp.ndarray,
                     out_dtype=None) -> jnp.ndarray:
    """G = a^T diag(C) ds  -> (d,p) or (L,d,p)."""
    out_dtype = out_dtype or a.dtype
    if a.ndim == 3:
        g = jnp.einsum("btd,b,btp->dp", a, C.astype(a.dtype), ds,
                       preferred_element_type=F32)
    elif a.ndim == 4:
        g = jnp.einsum("lbtd,b,lbtp->ldp", a, C.astype(a.dtype), ds,
                       preferred_element_type=F32)
    else:
        raise ValueError(f"mm record must be 3D or 4D, got {a.shape}")
    return g.astype(out_dtype)


# =========================================================== embedding (emb)
def sq_norm_emb(ids: jnp.ndarray, ds: jnp.ndarray) -> jnp.ndarray:
    """Ghost norm for embedding lookup (Li et al. 2021):
    ||g_i||^2 = sum_{t,t'} 1[id_t == id_t'] (ds_t . ds_t'). Returns (B,).
    Large records: lax.map over samples (one (T,T) pair live)."""
    if ids.ndim == 3:  # (L,B,T) stacked
        L = ids.shape[0]
        return sum(sq_norm_emb(ids[l], ds[l]) for l in range(L))
    B, T = ids.shape
    pe = dict(preferred_element_type=F32)
    if B * T * T <= MAP_THRESHOLD:
        eq = (ids[:, :, None] == ids[:, None, :]).astype(F32)
        gram_g = jnp.einsum("btd,bsd->bts", ds, ds, **pe)
        return jnp.einsum("bts,bts->b", eq, gram_g)

    def one(args):
        ib, gb = args
        eq = (ib[:, None] == ib[None, :]).astype(F32)
        gg = jnp.einsum("td,sd->ts", gb, gb, **pe)
        return jnp.sum(eq * gg)

    return jax.lax.map(one, (ids, ds))


def weighted_grad_emb(ids: jnp.ndarray, C: jnp.ndarray, ds: jnp.ndarray,
                      vocab: int, out_dtype=None) -> jnp.ndarray:
    """G = sum_i C_i sum_t onehot(id_it) ds_it  -> (V,d). Scatter-add."""
    out_dtype = out_dtype or ds.dtype
    if ids.ndim == 3:  # stacked embeddings: ONE segment-sum over all layers,
        # ids offset by l*vocab so each layer scatters into its own row block.
        # Out-of-range ids (pad/sentinel tokens) must keep the per-layer
        # scatter's drop semantics: route them to an OOB flat index instead
        # of letting the offset fold them into the next layer's rows.
        L, d = ids.shape[0], ds.shape[-1]
        w = (_f32(ds) * C[None, :, None, None]).reshape(-1, d)
        off = jnp.arange(L, dtype=ids.dtype)[:, None, None] * vocab
        valid = (ids >= 0) & (ids < vocab)
        flat_ids = jnp.where(valid, ids + off, L * vocab).reshape(-1)
        out = jnp.zeros((L * vocab, d), F32).at[flat_ids].add(w, mode="drop")
        return out.reshape(L, vocab, d).astype(out_dtype)
    w = (_f32(ds) * C[:, None, None]).reshape(-1, ds.shape[-1])
    flat_ids = ids.reshape(-1)
    out = jnp.zeros((vocab, ds.shape[-1]), F32).at[flat_ids].add(w)
    return out.astype(out_dtype)


# ================================================================= MoE (moe)
def _moe5(rec, ds):
    a, mask = rec["a"], rec["mask"]
    if a.ndim == 4:
        return a[None], mask[None], ds[None]
    if a.ndim == 5:
        return a, mask, ds
    raise ValueError(f"moe record must be 4D or 5D, got {a.shape}")


def sq_norm_moe_ghost(rec: dict, ds: jnp.ndarray) -> jnp.ndarray:
    """Ghost norm over capacity-gathered expert slots.

    rec['a'] (B,E,C,d) are each sample's tokens routed to each expert
    (zero-padded to capacity C, validity in rec['mask'] (B,E,C)); ds is the
    tap cotangent in the same layout. Per-(sample, expert) Gram over the C
    slots; norms sum over experts (the expert weights are disjoint
    parameters). Beyond-paper extension — the paper never treats MoE.
    Large records: lax.map over (layer,sample), one (E,C,C) Gram pair live.
    """
    a, mask, ds = _moe5(rec, ds)
    G, B, E, C, _ = a.shape
    pe = dict(preferred_element_type=F32)
    if G * B * E * C * C <= MAP_THRESHOLD:
        am = a * mask[..., None].astype(a.dtype)
        dm = ds * mask[..., None].astype(ds.dtype)
        gram_a = jnp.einsum("lbecd,lbefd->lbecf", am, am, **pe)
        gram_g = jnp.einsum("lbecp,lbefp->lbecf", dm, dm, **pe)
        return jnp.einsum("lbecf,lbecf->b", gram_a, gram_g, **pe)

    def one(args):
        ab, mb, gb = args
        am = ab * mb[..., None].astype(ab.dtype)
        dm = gb * mb[..., None].astype(gb.dtype)
        ga = jnp.einsum("ecd,efd->ecf", am, am, **pe)
        gg = jnp.einsum("ecp,efp->ecf", dm, dm, **pe)
        return jnp.sum(ga * gg)

    flat = lambda x: x.reshape((G * B,) + x.shape[2:])
    n = jax.lax.map(one, (flat(a), flat(mask), flat(ds)))
    return n.reshape(G, B).sum(0)


def sq_norm_moe_direct(rec: dict, ds: jnp.ndarray) -> jnp.ndarray:
    """Per-(sample,expert) gradient instantiation: g_{be} = a_be^T ds_be."""
    a, mask, ds = _moe5(rec, ds)
    G, B, E, _, d = a.shape
    p = ds.shape[-1]
    pe = dict(preferred_element_type=F32)
    if G * B * E * d * p <= MAP_THRESHOLD:
        dm = ds * mask[..., None].astype(ds.dtype)
        g = jnp.einsum("lbecd,lbecp->lbedp", a, dm, **pe)
        return jnp.einsum("lbedp,lbedp->b", g, g)

    def one(args):
        ab, mb, gb = args
        dm = gb * mb[..., None].astype(gb.dtype)
        g = jnp.einsum("ecd,ecp->edp", ab, dm, **pe)
        return jnp.sum(g * g)

    flat = lambda x: x.reshape((G * B,) + x.shape[2:])
    n = jax.lax.map(one, (flat(a), flat(mask), flat(ds)))
    return n.reshape(G, B).sum(0)


def weighted_grad_moe(rec: dict, C: jnp.ndarray, ds: jnp.ndarray,
                      out_dtype=None) -> jnp.ndarray:
    """G_e = sum_b C_b a_be^T ds_be  -> (E,d,p) or (L,E,d,p)."""
    a, mask = rec["a"], rec["mask"]
    out_dtype = out_dtype or a.dtype
    dsm = ds * mask[..., None].astype(ds.dtype)
    if a.ndim == 4:
        g = jnp.einsum("becd,b,becp->edp", a, C.astype(a.dtype), dsm,
                       preferred_element_type=F32)
    elif a.ndim == 5:
        g = jnp.einsum("lbecd,b,lbecp->ledp", a, C.astype(a.dtype), dsm,
                       preferred_element_type=F32)
    else:
        raise ValueError(f"moe record must be 4D or 5D, got {a.shape}")
    return g.astype(out_dtype)


# ====================================================== hybrid decision rule
def ghost_space(T: int) -> int:
    return 2 * T * T


def direct_space(d: int, p: int) -> int:
    return d * p


def prefer_ghost(T: int, d: int, p: int) -> bool:
    """Paper Sec. 3.2 layerwise rule: ghost norm iff 2 T^2 < p d."""
    return ghost_space(T) < direct_space(d, p)
