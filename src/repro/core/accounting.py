"""Privacy accounting: RDP of the Sampled Gaussian Mechanism (Mironov et al.
2019) + conversion to (eps, delta)-DP, plus sigma calibration.

Pure numpy (runs at config time, not in the training graph). The training
loop derives ``sigma`` from (target_epsilon, delta, sample_rate, steps), the
paper's Section 1.3 pipeline: accounting is independent of the clipping
threshold R.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np
from scipy.special import gammaln

DEFAULT_ORDERS = tuple([1.25, 1.5, 1.75, 2.0, 2.5, 3.0, 4.0, 5.0, 6.0, 8.0]
                       + list(range(10, 64))
                       + [72, 96, 128, 256, 512])


def _log_binom(n: int, k: np.ndarray) -> np.ndarray:
    return gammaln(n + 1) - gammaln(k + 1) - gammaln(n - k + 1)


def _log_a_int(q: float, sigma: float, alpha: int) -> float:
    """log A(alpha) for integer alpha >= 2 (Mironov et al. 2019, Sec 3.3)."""
    k = np.arange(alpha + 1, dtype=np.float64)
    terms = (_log_binom(alpha, k)
             + k * math.log(q)
             + (alpha - k) * math.log1p(-q)
             + (k * k - k) / (2.0 * sigma * sigma))
    m = terms.max()
    return float(m + np.log(np.sum(np.exp(terms - m))))


def _log_a_frac(q: float, sigma: float, alpha: float) -> float:
    """Fractional alpha via quadrature of
    A(alpha) = E_{z~N(0,s^2)} [((1-q) + q e^{(2z-1)/(2s^2)})^alpha]."""
    from scipy.integrate import quad

    s2 = sigma * sigma

    def integrand(z):
        logratio = np.logaddexp(math.log1p(-q),
                                math.log(q) + (2.0 * z - 1.0) / (2.0 * s2))
        log_f = (alpha * logratio - z * z / (2.0 * s2)
                 - 0.5 * math.log(2.0 * math.pi * s2))
        return np.exp(log_f)

    val, _ = quad(integrand, -np.inf, np.inf, limit=200)
    return float(np.log(val))


def rdp_sgm(q: float, sigma: float, alpha: float) -> float:
    """RDP epsilon of one SGM step at order alpha."""
    if q == 0.0:
        return 0.0
    if sigma == 0.0:
        return float("inf")
    if q == 1.0:
        return alpha / (2.0 * sigma * sigma)
    if float(alpha).is_integer():
        log_a = _log_a_int(q, sigma, int(alpha))
    else:
        log_a = _log_a_frac(q, sigma, alpha)
    return log_a / (alpha - 1.0)


def rdp_to_eps(rdp: np.ndarray, orders: np.ndarray, delta: float) -> float:
    """Improved RDP->(eps,delta) conversion (Balle et al. 2020, as in Opacus)."""
    orders = np.asarray(orders, dtype=np.float64)
    rdp = np.asarray(rdp, dtype=np.float64)
    eps = (rdp
           - (math.log(delta) + np.log(orders)) / (orders - 1.0)
           + np.log1p(-1.0 / orders))
    eps = np.where(np.isnan(eps), np.inf, eps)
    return float(max(0.0, np.min(eps)))


def compose_sensitivity(Rs) -> float:
    """L2 sensitivity of one sample's clipped contribution under group-wise
    clipping: each clipping unit bounds its slice of the per-sample gradient
    by R_u on disjoint coordinates, so the vector norm composes as
    sqrt(sum_u R_u^2) (He et al. 2022). A single flat unit recovers R."""
    return math.sqrt(sum(float(R) ** 2 for R in Rs))


def effective_sigma(sigmas) -> float:
    """Joint noise multiplier of heterogeneous per-group Gaussians
    (He et al. 2022 §4): group g's coordinates carry noise sigma_g * R_g
    with per-group sensitivity R_g on disjoint coordinate blocks, so the
    mean shift between neighbouring outputs reduces (along its own
    direction) to ONE Gaussian with multiplier
    (sum_g sigma_g^-2)^(-1/2). Uniform sigmas over k groups give
    sigma/sqrt(k) — per-group noise at the group's own sensitivity is
    strictly weaker than flat noise at the composed sensitivity, which is
    exactly why the joint accounting (not the flat bound) must be used."""
    sigmas = [float(s) for s in sigmas]
    if not sigmas:
        raise ValueError(
            "no noise multipliers to compose — the policy resolved to zero "
            "trainable clip units (all groups frozen?); there is no "
            "mechanism to account for")
    if any(s <= 0.0 for s in sigmas):
        return 0.0
    return sum(s ** -2 for s in sigmas) ** -0.5


def rdp_sgm_heterogeneous(q: float, sigmas, alpha: float) -> float:
    """RDP of ONE subsampled step releasing k per-group Gaussians on
    disjoint coordinate blocks with multipliers sigma_g (each relative to
    its own group's sensitivity).

    The per-group Gaussian RDP curves compose at the BASE-mechanism level:
    independent noise on disjoint blocks adds Renyi divergences,
    sum_g alpha/(2 sigma_g^2) = alpha/(2 effective_sigma^2), i.e. the block
    release is Renyi-identical to one Gaussian at ``effective_sigma``. The
    subsampling event is SHARED by every group (one batch draw), so the
    standard SGM curve then applies to that single equivalent Gaussian.
    (Composing k separately-subsampled per-group SGM curves instead would
    count the amplification k times and UNDER-report epsilon — invalid for
    the shared-batch mechanism this engine runs.)
    """
    return rdp_sgm(q, effective_sigma(sigmas), alpha)


@dataclass(frozen=True)
class PrivacyBudget:
    epsilon: float
    delta: float
    sigma: float
    sample_rate: float
    steps: int
    mechanism: str = "sgm"       # 'sgm' (subsampled Gaussian) | 'tree'


# ------------------------------------------------------------ spent-budget ledger
@dataclass(frozen=True)
class LedgerEntry:
    """One contiguous run segment accounted at fixed mechanism parameters."""
    steps: int
    sigma: float
    sample_rate: float
    mechanism: str = "sgm"       # 'sgm' | 'tree'
    restart_every: int = 0       # tree only
    participations: int = 1      # tree only

    def same_release(self, other: "LedgerEntry") -> bool:
        return (self.sigma, self.sample_rate, self.mechanism,
                self.restart_every) == \
               (other.sigma, other.sample_rate, other.mechanism,
                other.restart_every)


class PrivacyLedger:
    """Restart-safe spent-budget ledger.

    The ledger records which ABSOLUTE training steps have been accounted
    (``recorded_to`` = steps [0, recorded_to) are covered) together with the
    mechanism parameters in force over each contiguous segment. It is
    persisted inside every checkpoint (``checkpoint.run_state``) and resumed
    verbatim, so a mid-run restart reports epsilon for the WHOLE run, never
    "as if the run had just begun".

    ``record_to(step_end, ...)`` is idempotent over replayed steps: a crash
    after step k ran but before a checkpoint recorded it means the resumed
    run re-executes step k — but because every noise draw in this engine is
    a pure function of (seed, step) (counter-based Gaussian draws, fixed
    tree-node seeds), the re-executed step releases BITWISE the same
    randomness as the lost one. The adversary's view is identical to the
    uninterrupted run's, so counting each absolute step exactly once is the
    exact accounting, with neither leakage (no fresh noise reuse against a
    second query) nor double-counting (no budget charged twice for one
    release). Re-recording an already-covered range is therefore a no-op.

    Composition: 'sgm' segments compose additively in RDP (heterogeneous
    sigma across segments is honest composition). Contiguous 'tree'
    segments with identical (sigma, restart_every) are MERGED before
    accounting — they are one continued tree release whose node count grows
    with the total horizon (splitting them would re-count the shared
    near-root nodes); parameter changes start a new release, composed
    additively (an upper bound).
    """

    VERSION = 1

    def __init__(self, entries=(), recorded_to: int = 0):
        self.entries = [e if isinstance(e, LedgerEntry) else LedgerEntry(**e)
                        for e in entries]
        self.recorded_to = int(recorded_to)
        if sum(e.steps for e in self.entries) != self.recorded_to:
            raise ValueError(
                f"ledger entries cover {sum(e.steps for e in self.entries)} "
                f"steps but recorded_to={self.recorded_to}")

    def record_to(self, step_end: int, sigma: float, sample_rate: float,
                  mechanism: str = "sgm", restart_every: int = 0,
                  participations: int = 1) -> int:
        """Account steps [recorded_to, step_end); returns how many were new.
        ``step_end <= recorded_to`` (a replay after restart) is a no-op."""
        if mechanism not in ("sgm", "tree"):
            raise ValueError(f"unknown ledger mechanism {mechanism!r}")
        delta = int(step_end) - self.recorded_to
        if delta <= 0:
            return 0
        entry = LedgerEntry(delta, float(sigma), float(sample_rate),
                            mechanism, int(restart_every),
                            int(participations))
        if self.entries and self.entries[-1].same_release(entry):
            last = self.entries[-1]
            self.entries[-1] = LedgerEntry(
                last.steps + delta, last.sigma, last.sample_rate,
                last.mechanism, last.restart_every,
                max(last.participations, entry.participations))
        else:
            self.entries.append(entry)
        self.recorded_to = int(step_end)
        return delta

    def epsilon(self, delta: float, orders=DEFAULT_ORDERS) -> float:
        """(eps, delta) spent over every recorded step, composing segment
        RDP curves at shared orders and converting once."""
        if not self.entries:
            return 0.0
        orders = np.asarray(orders, dtype=np.float64)
        rdp = np.zeros_like(orders)
        for e in self._merged():
            if e.sigma <= 0.0:
                return float("inf")
            if e.mechanism == "tree":
                m = tree_node_count(e.steps, e.restart_every,
                                    e.participations)
                rdp = rdp + orders * m / (2.0 * e.sigma * e.sigma)
            else:
                rdp = rdp + np.array(
                    [e.steps * rdp_sgm(e.sample_rate, e.sigma, a)
                     for a in orders])
        return rdp_to_eps(rdp, orders, delta)

    def _merged(self):
        """Entries with contiguous same-release tree segments fused (the
        constructor/record_to already fuse; kept for from_json of hand-built
        histories)."""
        out = []
        for e in self.entries:
            if out and e.mechanism == "tree" and out[-1].same_release(e):
                last = out[-1]
                out[-1] = LedgerEntry(last.steps + e.steps, last.sigma,
                                      last.sample_rate, last.mechanism,
                                      last.restart_every,
                                      max(last.participations,
                                          e.participations))
            else:
                out.append(e)
        return out

    def to_json(self) -> dict:
        return {"version": self.VERSION, "recorded_to": self.recorded_to,
                "entries": [vars(e) for e in self.entries]}

    @classmethod
    def from_json(cls, data) -> "PrivacyLedger":
        if data is None:
            return cls()
        if int(data.get("version", 0)) != cls.VERSION:
            raise ValueError(
                f"unknown ledger version {data.get('version')!r} "
                f"(this build reads version {cls.VERSION})")
        return cls(entries=data.get("entries", ()),
                   recorded_to=data.get("recorded_to", 0))


# ------------------------------------------------- tree-aggregation accountant
def tree_node_count(steps: int, restart_every: int = 0,
                    participations: int = 1) -> int:
    """Max number of released tree nodes one sample's contributions touch.

    DP-FTRL (Kairouz et al. 2021) releases every binary-tree node sum, each
    perturbed with N(0, (sigma*S)^2). Each of a sample's ``participations``
    (its TOTAL appearances across the whole run — the number of data passes)
    lands in one leaf, whose root path touches at most the tree height
    h = floor(log2(next_pow2(E))) + 1 nodes, so the L2 sensitivity of the
    node-vector release is sqrt(m) * S with

        m <= participations * h_per_tree

    regardless of how the appearances distribute over restart epochs (paths
    in distinct trees are disjoint; multiple paths in one tree only overlap
    near the root, so the product is an upper bound either way). Restarts
    only shrink h — from the full-run tree's height to the epoch tree's —
    which is why restart-per-pass is the canonical multi-epoch setup.
    Honaker completion adds no nodes: the completed nodes are already
    counted by the full-tree height."""
    from repro.core.noise import next_pow2
    if steps <= 0:
        return 0
    horizon = restart_every if restart_every and restart_every > 0 else steps
    height = int(math.log2(next_pow2(horizon))) + 1
    return height * max(1, participations)


def compute_epsilon_tree(sigma: float, steps: int, delta: float,
                         restart_every: int = 0, participations: int = 1,
                         orders=DEFAULT_ORDERS) -> float:
    """(eps, delta) of the DP-FTRL tree-aggregation release.

    The full release (all node sums, each at noise sigma*S) is ONE Gaussian
    mechanism over a vector with L2 sensitivity sqrt(m)*S where m =
    ``tree_node_count`` — Gaussian RDP alpha*m/(2 sigma^2), converted with
    the same Balle et al. machinery as the SGM curve. No sampling assumption
    and no amplification: the bound holds for arbitrary (adversarial) data
    order, which is DP-FTRL's point."""
    if sigma <= 0.0:
        return float("inf")
    m = tree_node_count(steps, restart_every, participations)
    if m == 0:
        return 0.0
    orders = np.asarray(orders, dtype=np.float64)
    rdp = orders * m / (2.0 * sigma * sigma)
    return rdp_to_eps(rdp, orders, delta)


def calibrate_sigma_tree(target_epsilon: float, steps: int, delta: float,
                         restart_every: int = 0, participations: int = 1,
                         orders=DEFAULT_ORDERS, tol: float = 1e-3) -> float:
    """Smallest sigma achieving eps <= target under tree aggregation."""
    lo, hi = 0.1, 1.0
    eps = lambda s: compute_epsilon_tree(s, steps, delta, restart_every,
                                         participations, orders)
    while eps(hi) > target_epsilon:
        hi *= 2.0
        if hi > 1e6:
            raise ValueError("cannot reach target epsilon")
    while eps(lo) < target_epsilon:
        lo /= 2.0
        if lo < 1e-6:
            return lo
    while hi - lo > tol:
        mid = 0.5 * (lo + hi)
        if eps(mid) > target_epsilon:
            lo = mid
        else:
            hi = mid
    return hi


def compute_epsilon(sigma, sample_rate: float, steps: int,
                    delta: float, orders=DEFAULT_ORDERS) -> float:
    """(eps, delta) after ``steps`` SGM compositions.

    ``sigma`` is either one noise multiplier (the flat scheme) or a sequence
    of per-group multipliers — ``ResolvedPolicy.noise_multipliers()`` — in
    which case the heterogeneous joint bound is composed. With every
    sigma_scale at 1.0 the multiplier list is sigma * S/R_u per unit and the
    joint bound reproduces the flat single-sigma bound exactly."""
    if np.ndim(sigma) > 0:
        rdp = np.array([steps * rdp_sgm_heterogeneous(sample_rate, sigma, a)
                        for a in orders])
    else:
        rdp = np.array([steps * rdp_sgm(sample_rate, float(sigma), a)
                        for a in orders])
    return rdp_to_eps(rdp, np.array(orders), delta)


def calibrate_sigma(target_epsilon: float, sample_rate: float, steps: int,
                    delta: float, orders=DEFAULT_ORDERS,
                    tol: float = 1e-3) -> float:
    """Smallest sigma achieving eps <= target, via bisection."""
    lo, hi = 0.1, 1.0
    while compute_epsilon(hi, sample_rate, steps, delta, orders) > target_epsilon:
        hi *= 2.0
        if hi > 1e4:
            raise ValueError("cannot reach target epsilon")
    while compute_epsilon(lo, sample_rate, steps, delta, orders) < target_epsilon:
        lo /= 2.0
        if lo < 1e-6:
            return lo
    while hi - lo > tol:
        mid = 0.5 * (lo + hi)
        if compute_epsilon(mid, sample_rate, steps, delta, orders) > target_epsilon:
            lo = mid
        else:
            hi = mid
    return hi


def budget_for(target_epsilon: float, delta: float, batch_size: int,
               dataset_size: int, epochs: float, mechanism: str = "sgm",
               restart_every: int = 0) -> PrivacyBudget:
    """The PrivacyEngine entry point, mirroring the paper's Sec. 4 API.

    ``mechanism='sgm'`` (default) calibrates against the subsampled-Gaussian
    curve — DP-SGD with Poisson-style sampling. ``mechanism='tree'``
    calibrates against the tree-aggregation release (DP-FTRL: no sampling
    assumption, no amplification) with the FTRL restart period; the sample's
    participation count is the number of data passes (>= 1)."""
    q = batch_size / dataset_size
    steps = int(math.ceil(epochs * dataset_size / batch_size))
    if mechanism == "tree":
        participations = max(1, int(math.ceil(epochs)))
        sigma = calibrate_sigma_tree(target_epsilon, steps, delta,
                                     restart_every, participations)
        eps = compute_epsilon_tree(sigma, steps, delta, restart_every,
                                   participations)
    elif mechanism == "sgm":
        sigma = calibrate_sigma(target_epsilon, q, steps, delta)
        eps = compute_epsilon(sigma, q, steps, delta)
    else:
        raise ValueError(f"unknown accounting mechanism {mechanism!r}; "
                         "options: 'sgm', 'tree'")
    return PrivacyBudget(eps, delta, sigma, q, steps, mechanism)
