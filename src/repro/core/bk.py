"""The Book-Keeping (BK) engine — Algorithm 1 of the paper, JAX-native.

One jax.vjp w.r.t. (taps, per-sample params) yields, in a SINGLE
back-propagation and without ever instantiating per-sample weight gradients:

  * every layer's output gradient dL/ds_(l)      (tap cotangents — book-keeping)
  * per-sample gradients of vector params (B,..) (psp cotangents — the 0.1%)

and because the weights themselves are not differentiated, XLA never emits
the non-private parameter-gradient matmuls (ghost differentiation).

Phases (all inside one jit-able pure function):
  1. fwd + output-grad bwd via vjp            — modules 1 + 2a
  2. per-sample squared norms per tapped op   — module 3 (ghost) or 4 (direct)
     + vector-param norms; aggregate across layers; clip factors C_i
  3. weighted gradients G_l = a^T diag(C) ds  — module 2b'/5
  4. Gaussian noise, scale by 1/B

Modes:
  'bk'           ghost norm everywhere (base BK)
  'bk-mixghost'  layerwise ghost-vs-direct for the *norm* only
  'bk-mixopt'    layerwise for norm AND weighted grad (reuses instantiated
                 per-sample grads for module 5 when direct is chosen)
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import ghost
from repro.core.clipping import get_clip_fn
from repro.core.policy import (as_policy, finalize_noise, norm_aux,
                               resolve_policy, unit_clip_factors)
from repro.core.tape import Tape, parse_key
from repro.utils.tree import flatten, unflatten

F32 = jnp.float32

BK_MODES = ("bk", "bk-mixghost", "bk-mixopt")


@dataclass(frozen=True)
class DPConfig:
    clipping: str = "automatic"      # clipping fn name (core.clipping)
    R: float = 1.0                   # clipping threshold / normalizer
    sigma: float = 0.0               # noise multiplier (0 = clipping only)
    mode: str = "bk"                 # implementation (BK_MODES + baselines)
    use_kernels: bool = True         # fused Pallas kernels via kernels.dispatch
    gamma: float = 0.01              # automatic-clipping stability constant

    def clip_fn(self) -> Callable:
        kw = {"gamma": self.gamma} if self.clipping == "automatic" else {}
        return get_clip_fn(self.clipping, self.R, **kw)


# --------------------------------------------------------------------- utils
def batch_size_of(batch: dict) -> int:
    return jax.tree_util.tree_leaves(batch)[0].shape[0]


def tap_structs(apply_fn, params, batch):
    """Tap zero-structure via one (free) eval_shape pass."""

    def shape_run(p, b):
        tape = Tape(None)
        apply_fn(p, b, tape)
        return tape.tap_zeros

    return jax.eval_shape(shape_run, params, batch)


def split_param_paths(params, tap_struct):
    """-> (ghost_w_paths, psp_paths). Ghost leaves are '<tap path>/w'."""
    flat = flatten(params)
    tapped = {parse_key(k)[0] + "/w" for k in tap_struct}
    ghost_paths = sorted(p for p in flat if p in tapped)
    psp_paths = sorted(p for p in flat if p not in tapped)
    missing = tapped - set(flat)
    if missing:
        raise ValueError(f"tapped ops without matching '<path>/w' param: {sorted(missing)}")
    dead = [p for p in psp_paths if p.endswith("/w")]
    if dead:
        raise ValueError(
            "untapped weight params (dead or mis-named tap — every '/w' leaf "
            f"must belong to a tapped generalized-linear op): {dead}")
    return ghost_paths, psp_paths


# ------------------------------------------------------------- norm dispatch
def record_sq_norm(key: str, act, ds, mode: str, use_kernels: bool,
                   method: str = ""):
    """Per-sample squared norm for one tapped op.

    Every kind routes through kernels.dispatch: the plan fixes ghost-vs-direct
    (the paper's layerwise rule; mode 'bk' forces ghost; a ParamGroup's
    ``method`` override wins over both) and, when ``use_kernels``, whether the
    fused Pallas kernel or the jnp einsum runs plus its block sizes. Returns
    (sq_norms (B,), cached) where cached optionally carries the instantiated
    per-sample grads for mixopt reuse in phase 3.
    """
    from repro.kernels import dispatch
    _, kind, _ = parse_key(key)
    if kind == "mm":
        plan = dispatch.norm_plan("mm", act.shape, ds.shape, mode, method)
        fused = use_kernels and plan.impl == "kernel"
        if plan.method == "ghost":
            if fused:
                from repro.kernels import ops as kops
                return kops.ghost_norm_mm(act, ds, **plan.kwargs()), None
            return ghost.sq_norm_mm_ghost(act, ds), None
        B, d, p = act.shape[-3], act.shape[-1], ds.shape[-1]
        L = act.shape[0] if act.ndim == 4 else 1
        small = L * B * d * p <= ghost.MAP_THRESHOLD
        if mode == "bk-mixopt" and small:
            # mixopt's defining move (paper Sec 3.3): instantiate once, reuse
            # for module 5 in phase 3. Takes precedence over the fused kernel
            # — the kernel saves the per-sample-grad space, but mixopt chose
            # direct *because* it is willing to spend that space to halve the
            # phase-3 FLOPs; only cache when cheap to keep (else re-einsum)
            eq = "lbtd,lbtp->lbdp" if act.ndim == 4 else "btd,btp->bdp"
            g = jnp.einsum(eq, act.astype(F32), ds.astype(F32))
            axes = tuple(i for i in range(g.ndim) if i != (1 if g.ndim == 4 else 0))
            return jnp.sum(g * g, axis=axes), g
        if fused:
            from repro.kernels import ops as kops
            return kops.direct_norm_mm(act, ds, **plan.kwargs()), None
        return ghost.sq_norm_mm_direct(act, ds), None
    if kind == "emb":
        plan = dispatch.norm_plan("emb", act.shape, ds.shape, mode, method)
        if use_kernels and plan.impl == "kernel":
            from repro.kernels import ops as kops
            return kops.ghost_norm_emb(act, ds, **plan.kwargs()), None
        return ghost.sq_norm_emb(act, ds), None
    if kind == "moe":
        plan = dispatch.norm_plan("moe", act["a"].shape, ds.shape, mode,
                                  method)
        fused = use_kernels and plan.impl == "kernel"
        if plan.method == "ghost":
            if fused:
                from repro.kernels import ops as kops
                return kops.ghost_norm_moe(act, ds), None
            return ghost.sq_norm_moe_ghost(act, ds), None
        if fused:
            from repro.kernels import ops as kops
            return kops.direct_norm_moe(act, ds, **plan.kwargs()), None
        return ghost.sq_norm_moe_direct(act, ds), None
    raise ValueError(f"unknown tap kind in key {key!r}")


def record_weighted_grad(key: str, act, ds, C, cached, use_kernels: bool,
                         out_dtype, vocab: int = 0):
    from repro.kernels import dispatch
    _, kind, _ = parse_key(key)
    if kind == "mm":
        if cached is not None:  # mixopt module-5 reuse: sum_i C_i g_i (2Bpd)
            eq = "lbdp,b->ldp" if cached.ndim == 4 else "bdp,b->dp"
            return jnp.einsum(eq, cached, C.astype(F32)).astype(out_dtype)
        if use_kernels:
            plan = dispatch.grad_plan("mm", act.shape, ds.shape)
            if plan.impl == "kernel":
                from repro.kernels import ops as kops
                return kops.clipped_grad_mm(act, C, ds,
                                            **plan.kwargs()).astype(out_dtype)
        return ghost.weighted_grad_mm(act, C, ds, out_dtype)
    if kind == "emb":
        if use_kernels:
            plan = dispatch.grad_plan("emb", act.shape, ds.shape, vocab)
            if plan.impl == "kernel":
                from repro.kernels import ops as kops
                return kops.clipped_grad_emb(act, C, ds, vocab,
                                             **plan.kwargs()).astype(out_dtype)
        return ghost.weighted_grad_emb(act, C, ds, vocab, out_dtype)
    if kind == "moe":
        if use_kernels:
            plan = dispatch.grad_plan("moe", act["a"].shape, ds.shape)
            if plan.impl == "kernel":
                from repro.kernels import ops as kops
                return kops.clipped_grad_moe(act, C, ds,
                                             **plan.kwargs()).astype(out_dtype)
        return ghost.weighted_grad_moe(act, C, ds, out_dtype)
    raise ValueError(f"unknown tap kind in key {key!r}")


def plan_report(apply_fn, params, batch, cfg) -> dict:
    """Resolved kernel-dispatch plans per tap, from one free eval_shape pass.

    -> {tap_key: {'norm': Plan, 'grad': Plan}} — observability for the
    engine/benchmarks; no compute. Policy-aware: frozen-group taps are
    absent from the report (they emit no norm/grad work at all) and
    per-group method overrides show up in the norm plan."""
    from repro.kernels import dispatch
    policy = as_policy(cfg)

    def shape_run(p, b):
        tape = Tape(None)
        apply_fn(p, b, tape)
        return tape.tap_zeros, tape.acts

    taps, acts = jax.eval_shape(shape_run, params, batch)
    flat_params = flatten(params)
    res = resolve_policy(policy, flat_params)
    report = {}
    for key in sorted(acts):
        path, kind, _ = parse_key(key)
        wpath = path + "/w"
        if wpath in res.frozen:
            continue
        a_shape = acts[key]["a"].shape if kind == "moe" else acts[key].shape
        vocab = flat_params[wpath].shape[-2] if kind == "emb" else 0
        plans = {
            "norm": dispatch.norm_plan(kind, a_shape, taps[key].shape,
                                       policy.mode, res.method_for(wpath)),
            "grad": dispatch.grad_plan(kind, a_shape, taps[key].shape, vocab),
        }
        if not policy.use_kernels:  # report what will actually run
            plans = {k: replace(p, impl="jnp") for k, p in plans.items()}
        report[key] = plans
    return report


# ------------------------------------------------------------------- BK core
def bk_clipped_sum(apply_fn, params, batch, cfg):
    """Phases 1-3 of BK: the pre-noise clipped gradient SUM (flat dict).

    ``cfg`` is a DPConfig or PrivacyPolicy; each clipping unit of the
    resolved policy gets its own per-sample norm accumulator and clip factor
    C_i^(u), frozen-group taps/params are skipped outright (no cotangent is
    even requested — XLA never builds their book-keeping), and their grads
    come back as zeros.

    This is the accumulation unit for the physical/logical batch split
    (paper footnote 2): sum over microbatches, then noise ONCE per logical
    batch. Returns (flat_sums, aux)."""
    policy = as_policy(cfg)
    assert policy.mode in BK_MODES, policy.mode
    B = batch_size_of(batch)
    flat_params = flatten(params)
    tap_struct = tap_structs(apply_fn, params, batch)
    _, psp_paths = split_param_paths(params, tap_struct)
    res = resolve_policy(policy, flat_params)

    active_taps = sorted(k for k in tap_struct
                         if parse_key(k)[0] + "/w" not in res.frozen)
    psp_active = [p for p in psp_paths if p not in res.frozen]
    taps0 = {k: jnp.zeros(tap_struct[k].shape, tap_struct[k].dtype)
             for k in active_taps}
    psp0 = {p: jnp.broadcast_to(flat_params[p], (B,) + flat_params[p].shape)
            for p in psp_active}

    # ---- phase 1: one forward + one output-gradient-only backward ----------
    def run(taps, psp):
        merged = dict(flat_params)
        merged.update(psp)
        tape = Tape(taps)
        losses = apply_fn(unflatten(merged), batch, tape)
        return jnp.sum(losses), (losses, tape.acts)

    loss_sum, vjp_fn, (losses, acts) = jax.vjp(run, taps0, psp0, has_aux=True)
    ds_taps, g_psp = vjp_fn(jnp.ones_like(loss_sum))

    # ---- phase 2: per-unit per-sample norms + clip factors ------------------
    unit_of = lambda p: res.unit_of[p]
    sq = [jnp.zeros((B,), F32) for _ in res.units]
    cache = {}
    for key in active_taps:
        wpath = parse_key(key)[0] + "/w"
        nk, cached = record_sq_norm(key, acts[key], ds_taps[key], policy.mode,
                                    policy.use_kernels,
                                    res.method_for(wpath))
        cache[key] = cached
        u = unit_of(wpath)
        sq[u] = sq[u] + nk
    for p in psp_active:
        g = g_psp[p].astype(F32)
        u = unit_of(p)
        sq[u] = sq[u] + jnp.sum(g * g, axis=tuple(range(1, g.ndim)))
    unit_norms, unit_C = unit_clip_factors(res, sq)

    # ---- phase 3: weighted gradients ----------------------------------------
    flat_grads = {}
    for key in active_taps:
        path, kind, _ = parse_key(key)
        wpath = path + "/w"
        w = flat_params[wpath]
        vocab = w.shape[-2] if kind == "emb" else 0
        flat_grads[wpath] = record_weighted_grad(
            key, acts[key], ds_taps[key], unit_C[unit_of(wpath)], cache[key],
            policy.use_kernels, w.dtype, vocab)
    for p in psp_active:
        g = g_psp[p]
        flat_grads[p] = jnp.einsum("b...,b->...", g.astype(F32),
                                   unit_C[unit_of(p)]).astype(
                                       flat_params[p].dtype)
    for p in res.frozen:
        flat_grads[p] = jnp.zeros_like(flat_params[p])

    return flat_grads, norm_aux(res, losses, sq, unit_norms, unit_C)


def bk_private_grad(apply_fn, params, batch, rng, cfg, step=None):
    """Private gradient via Book-Keeping: clipped sum + noise + 1/B scale.
    ``step`` feeds stateful noise mechanisms (tree aggregation raises when it
    is omitted); the default Gaussian ignores it. Returns (grads matching the
    params tree, aux)."""
    policy = as_policy(cfg)
    B = batch_size_of(batch)
    flat_sums, aux = bk_clipped_sum(apply_fn, params, batch, policy)
    # ---- phase 4: noise (sigma * sigma_scale_u * composed S per unit) + scale
    res = resolve_policy(policy, flatten(params))
    flat_grads = finalize_noise(policy, res, flat_sums, rng, float(B), step)
    return unflatten(flat_grads), aux
