"""The Book-Keeping (BK) engine — Algorithm 1 of the paper, JAX-native.

One jax.vjp w.r.t. (taps, per-sample params) yields, in a SINGLE
back-propagation and without ever instantiating per-sample weight gradients:

  * every layer's output gradient dL/ds_(l)      (tap cotangents — book-keeping)
  * per-sample gradients of vector params (B,..) (psp cotangents — the 0.1%)

and because the weights themselves are not differentiated, XLA never emits
the non-private parameter-gradient matmuls (ghost differentiation).

Phases (all inside one jit-able pure function):
  1. fwd + output-grad bwd via vjp            — modules 1 + 2a
  2. per-sample squared norms per tapped op   — module 3 (ghost) or 4 (direct)
     + vector-param norms; aggregate across layers; clip factors C_i
  3. weighted gradients G_l = a^T diag(C) ds  — module 2b'/5
  4. Gaussian noise, scale by 1/B

Modes:
  'bk'           ghost norm everywhere (base BK)
  'bk-mixghost'  layerwise ghost-vs-direct for the *norm* only
  'bk-mixopt'    layerwise for norm AND weighted grad (reuses instantiated
                 per-sample grads for module 5 when direct is chosen)

Mesh lowering: every entry point takes an optional ``mesh``. Under a mesh
whose batch axes divide B, the per-sample record compute stays batch-sharded
end to end — fused kernels run inside a shard_map on their local batch shard
(per-sample norms reduce at size B_local and STAY sharded; each weighted
gradient pays exactly one psum over the batch axes), the jnp paths get
sharding constraints so GSPMD keeps the same layout, and phase-4 noise is
generated shard-local (see core.noise.sharded_normal).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import ghost
from repro.core.clipping import get_clip_fn
from repro.core.policy import (as_policy, finalize_noise, norm_aux,
                               resolve_policy, unit_clip_factors)
from repro.core.tape import Tape, load_record, parse_key, store_record
from repro.utils.tree import flatten, unflatten

F32 = jnp.float32

BK_MODES = ("bk", "bk-mixghost", "bk-mixopt")


# ----------------------------------------------------------- mesh lowering
def mesh_batch_axes(mesh) -> tuple:
    """Mesh axes the batch dim shards over (mirrors launch.mesh.batch_axes;
    duplicated here so core never imports launch)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_shard(mesh, B: int):
    """-> (batch_axes, n_shards) when ``mesh`` can split B, else None."""
    if mesh is None:
        return None
    ba = mesh_batch_axes(mesh)
    n = 1
    for a in ba:
        n *= mesh.shape[a]
    if n <= 1 or B % n:
        return None
    return ba, n


def _bspec(ndim: int, bdim: int, ba) -> P:
    return P(*(ba if i == bdim else None for i in range(ndim)))


def _constrain(x, mesh, spec):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _shard_call(mesh, fn, args, in_specs, out_specs, psum_axes=None):
    """Run a per-sample kernel batch-sharded: each device computes its local
    batch slice; ``psum_axes`` reduces sum-typed outputs (weighted grads)
    once across the batch axes — the single cross-device reduction per clip
    unit the mesh-lowered step pays."""
    from jax.experimental.shard_map import shard_map
    body = fn
    if psum_axes:
        body = lambda *a: jax.lax.psum(fn(*a), psum_axes)
    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)(*args)


def _local(shape, bdim: int, n: int) -> tuple:
    return tuple(s // n if i == bdim else s for i, s in enumerate(shape))


@dataclass(frozen=True)
class DPConfig:
    clipping: str = "automatic"      # clipping fn name (core.clipping)
    R: float = 1.0                   # clipping threshold / normalizer
    sigma: float = 0.0               # noise multiplier (0 = clipping only)
    mode: str = "bk"                 # implementation (BK_MODES + baselines)
    use_kernels: bool = True         # fused Pallas kernels via kernels.dispatch
    gamma: float = 0.01              # automatic-clipping stability constant
    tape_policy: str = "native"      # tap-record residency between phases 2-3
                                     # (core.tape.TAPE_POLICIES: native | bf16
                                     # | int8 | recompute | auto)
    tape_chunks: int = 1             # phase-3 re-derivation chunks (recompute)

    def clip_fn(self) -> Callable:
        kw = {"gamma": self.gamma} if self.clipping == "automatic" else {}
        return get_clip_fn(self.clipping, self.R, **kw)


# --------------------------------------------------------------------- utils
def batch_size_of(batch: dict) -> int:
    return jax.tree_util.tree_leaves(batch)[0].shape[0]


def tap_structs(apply_fn, params, batch):
    """Tap zero-structure via one (free) eval_shape pass."""
    return tap_act_structs(apply_fn, params, batch)[0]


def tap_act_structs(apply_fn, params, batch):
    """-> (tap zero structure, activation-record structure), one free
    eval_shape pass (the residency planner needs both shapes)."""

    def shape_run(p, b):
        tape = Tape(None)
        apply_fn(p, b, tape)
        return tape.tap_zeros, tape.acts

    return jax.eval_shape(shape_run, params, batch)


def _tap_w(key: str) -> str:
    return parse_key(key)[0] + "/w"


def split_param_paths(params, tap_struct):
    """-> (ghost_w_paths, psp_paths). Ghost leaves are '<tap path>/w'."""
    flat = flatten(params)
    tapped = {parse_key(k)[0] + "/w" for k in tap_struct}
    ghost_paths = sorted(p for p in flat if p in tapped)
    psp_paths = sorted(p for p in flat if p not in tapped)
    missing = tapped - set(flat)
    if missing:
        raise ValueError(f"tapped ops without matching '<path>/w' param: {sorted(missing)}")
    dead = [p for p in psp_paths if p.endswith("/w")]
    if dead:
        raise ValueError(
            "untapped weight params (dead or mis-named tap — every '/w' leaf "
            f"must belong to a tapped generalized-linear op): {dead}")
    return ghost_paths, psp_paths


# ------------------------------------------------------------- norm dispatch
def record_sq_norm(key: str, act, ds, mode: str, use_kernels: bool,
                   method: str = "", mesh=None, shard=None,
                   allow_cache: bool = True):
    """Per-sample squared norm for one tapped op.

    Every kind routes through kernels.dispatch: the plan fixes ghost-vs-direct
    (the paper's layerwise rule; mode 'bk' forces ghost; a ParamGroup's
    ``method`` override wins over both) and, when ``use_kernels``, whether the
    fused Pallas kernel or the jnp einsum runs plus its block sizes. Returns
    (sq_norms (B,), cached) where cached optionally carries the instantiated
    per-sample grads for mixopt reuse in phase 3. ``allow_cache=False``
    suppresses that instantiation — mixopt's cache is itself a residency
    decision, and a non-native tape policy overrides it (the streamed
    engine then holds the compressed cotangent, or nothing, instead).

    With ``shard`` = (batch_axes, n) the kernel runs inside a shard_map on
    its local batch slice (the plan is fitted to the LOCAL shapes, matching
    what each device executes) and the (B,) norms come back batch-sharded;
    jnp paths are left to GSPMD.
    """
    from repro.kernels import dispatch
    _, kind, _ = parse_key(key)
    ba, n = shard if shard else ((), 1)
    if kind == "mm":
        bdim = act.ndim - 3
        a_shape = _local(act.shape, bdim, n)
        ds_shape = _local(ds.shape, bdim, n)
        plan = dispatch.norm_plan("mm", a_shape, ds_shape, mode, method)
        fused = use_kernels and plan.impl == "kernel"
        if plan.method == "ghost":
            if fused:
                from repro.kernels import ops as kops
                fn = lambda a, d: kops.ghost_norm_mm(a, d, **plan.kwargs())
                if n > 1:
                    return _shard_call(
                        mesh, fn, (act, ds),
                        (_bspec(act.ndim, bdim, ba),
                         _bspec(ds.ndim, bdim, ba)), P(ba)), None
                return fn(act, ds), None
            return ghost.sq_norm_mm_ghost(act, ds), None
        B, d, p = act.shape[-3], act.shape[-1], ds.shape[-1]
        L = act.shape[0] if act.ndim == 4 else 1
        # the cache lives batch-sharded: its footprint (and the decision to
        # keep it) is per-device, like the kernel plans above
        small = L * (B // n) * d * p <= ghost.MAP_THRESHOLD
        if mode == "bk-mixopt" and small and allow_cache:
            # mixopt's defining move (paper Sec 3.3): instantiate once, reuse
            # for module 5 in phase 3. Takes precedence over the fused kernel
            # — the kernel saves the per-sample-grad space, but mixopt chose
            # direct *because* it is willing to spend that space to halve the
            # phase-3 FLOPs; only cache when cheap to keep (else re-einsum)
            eq = "lbtd,lbtp->lbdp" if act.ndim == 4 else "btd,btp->bdp"
            g = jnp.einsum(eq, act.astype(F32), ds.astype(F32))
            axes = tuple(i for i in range(g.ndim) if i != (1 if g.ndim == 4 else 0))
            return jnp.sum(g * g, axis=axes), g
        if fused:
            from repro.kernels import ops as kops
            fn = lambda a, d: kops.direct_norm_mm(a, d, **plan.kwargs())
            if n > 1:
                return _shard_call(
                    mesh, fn, (act, ds),
                    (_bspec(act.ndim, bdim, ba),
                     _bspec(ds.ndim, bdim, ba)), P(ba)), None
            return fn(act, ds), None
        return ghost.sq_norm_mm_direct(act, ds), None
    if kind == "emb":
        bdim = act.ndim - 2
        plan = dispatch.norm_plan("emb", _local(act.shape, bdim, n),
                                  _local(ds.shape, bdim, n), mode, method)
        if use_kernels and plan.impl == "kernel":
            from repro.kernels import ops as kops
            fn = lambda i, d: kops.ghost_norm_emb(i, d, **plan.kwargs())
            if n > 1:
                return _shard_call(
                    mesh, fn, (act, ds),
                    (_bspec(act.ndim, bdim, ba),
                     _bspec(ds.ndim, bdim, ba)), P(ba)), None
            return fn(act, ds), None
        return ghost.sq_norm_emb(act, ds), None
    if kind == "moe":
        a = act["a"]
        bdim = a.ndim - 4
        plan = dispatch.norm_plan("moe", _local(a.shape, bdim, n),
                                  _local(ds.shape, bdim, n), mode, method)
        fused = use_kernels and plan.impl == "kernel"
        rec_specs = {"a": _bspec(a.ndim, bdim, ba),
                     "mask": _bspec(act["mask"].ndim, bdim, ba)} if n > 1 \
            else None
        if plan.method == "ghost":
            if fused:
                from repro.kernels import ops as kops
                if n > 1:
                    return _shard_call(
                        mesh, kops.ghost_norm_moe, (act, ds),
                        (rec_specs, _bspec(ds.ndim, bdim, ba)), P(ba)), None
                return kops.ghost_norm_moe(act, ds), None
            return ghost.sq_norm_moe_ghost(act, ds), None
        if fused:
            from repro.kernels import ops as kops
            fn = lambda r, d: kops.direct_norm_moe(r, d, **plan.kwargs())
            if n > 1:
                return _shard_call(
                    mesh, fn, (act, ds),
                    (rec_specs, _bspec(ds.ndim, bdim, ba)), P(ba)), None
            return fn(act, ds), None
        return ghost.sq_norm_moe_direct(act, ds), None
    raise ValueError(f"unknown tap kind in key {key!r}")


def record_weighted_grad(key: str, act, ds, C, cached, use_kernels: bool,
                         out_dtype, vocab: int = 0, mesh=None, shard=None):
    """Phase-3 weighted gradient G = a^T diag(C) ds for one tap. Under
    ``shard`` each device contracts its local batch slice and the partial
    sums meet in ONE psum over the batch axes — the only cross-device
    reduction the clipped sum pays."""
    from repro.kernels import dispatch
    _, kind, _ = parse_key(key)
    ba, n = shard if shard else ((), 1)
    if kind == "mm":
        if cached is not None:  # mixopt module-5 reuse: sum_i C_i g_i (2Bpd)
            eq = "lbdp,b->ldp" if cached.ndim == 4 else "bdp,b->dp"
            return jnp.einsum(eq, cached, C.astype(F32)).astype(out_dtype)
        if use_kernels:
            bdim = act.ndim - 3
            plan = dispatch.grad_plan("mm", _local(act.shape, bdim, n),
                                      _local(ds.shape, bdim, n))
            if plan.impl == "kernel":
                from repro.kernels import ops as kops
                fn = lambda a, c, d: kops.clipped_grad_mm(a, c, d,
                                                          **plan.kwargs())
                if n > 1:
                    return _shard_call(
                        mesh, fn, (act, C, ds),
                        (_bspec(act.ndim, bdim, ba), P(ba),
                         _bspec(ds.ndim, bdim, ba)), P(),
                        psum_axes=ba).astype(out_dtype)
                return fn(act, C, ds).astype(out_dtype)
        return ghost.weighted_grad_mm(act, C, ds, out_dtype)
    if kind == "emb":
        if use_kernels:
            bdim = act.ndim - 2
            plan = dispatch.grad_plan("emb", _local(act.shape, bdim, n),
                                      _local(ds.shape, bdim, n), vocab)
            if plan.impl == "kernel":
                from repro.kernels import ops as kops
                fn = lambda i, c, d: kops.clipped_grad_emb(i, c, d, vocab,
                                                           **plan.kwargs())
                if n > 1:
                    return _shard_call(
                        mesh, fn, (act, C, ds),
                        (_bspec(act.ndim, bdim, ba), P(ba),
                         _bspec(ds.ndim, bdim, ba)), P(),
                        psum_axes=ba).astype(out_dtype)
                return fn(act, C, ds).astype(out_dtype)
        return ghost.weighted_grad_emb(act, C, ds, vocab, out_dtype)
    if kind == "moe":
        if use_kernels:
            a = act["a"]
            bdim = a.ndim - 4
            plan = dispatch.grad_plan("moe", _local(a.shape, bdim, n),
                                      _local(ds.shape, bdim, n))
            if plan.impl == "kernel":
                from repro.kernels import ops as kops
                fn = lambda r, c, d: kops.clipped_grad_moe(r, c, d,
                                                           **plan.kwargs())
                if n > 1:
                    rec_specs = {"a": _bspec(a.ndim, bdim, ba),
                                 "mask": _bspec(act["mask"].ndim, bdim, ba)}
                    return _shard_call(
                        mesh, fn, (act, C, ds),
                        (rec_specs, P(ba), _bspec(ds.ndim, bdim, ba)), P(),
                        psum_axes=ba).astype(out_dtype)
                return fn(act, C, ds).astype(out_dtype)
        return ghost.weighted_grad_moe(act, C, ds, out_dtype)
    raise ValueError(f"unknown tap kind in key {key!r}")


def plan_report(apply_fn, params, batch, cfg) -> dict:
    """Resolved kernel-dispatch plans per tap, from one free eval_shape pass.

    -> {tap_key: {'norm': Plan, 'grad': Plan, 'tape': TapePlan}} —
    observability for the engine/benchmarks; no compute. Policy-aware:
    frozen-group taps are absent from the report (they emit no norm/grad
    work at all), per-group method overrides show up in the norm plan, and
    the 'tape' entry is the tap's resolved residency decision (group
    ``tape`` override / policy ``tape_policy`` / planner 'auto') with its
    held-bytes and re-derivation-FLOPs cost numbers."""
    from repro.kernels import dispatch
    policy = as_policy(cfg)

    taps, acts = tap_act_structs(apply_fn, params, batch)
    flat_params = flatten(params)
    res = resolve_policy(policy, flat_params)
    active = sorted(k for k in taps if _tap_w(k) not in res.frozen)
    tape_pol = resolve_tape(policy, res, {k: taps[k] for k in active}, acts)
    stream_keys = _streamed_taps(res, active)
    report = {}
    for key in sorted(acts):
        path, kind, _ = parse_key(key)
        wpath = path + "/w"
        if wpath in res.frozen:
            continue
        a_shape = acts[key]["a"].shape if kind == "moe" else acts[key].shape
        vocab = flat_params[wpath].shape[-2] if kind == "emb" else 0
        plans = {
            "norm": dispatch.norm_plan(kind, a_shape, taps[key].shape,
                                       policy.mode, res.method_for(wpath)),
            "grad": dispatch.grad_plan(kind, a_shape, taps[key].shape, vocab),
        }
        if key in stream_keys:
            # streamed single-tap unit: phases 2+3 fuse at this tap — the
            # 'fused' plan says HOW (one kernel launch vs composed split)
            # and the 'stream' tape entry records that nothing is held
            plans["fused"] = dispatch.fused_plan(kind, a_shape,
                                                 taps[key].shape, policy.mode,
                                                 res.method_for(wpath))
        if not policy.use_kernels:  # report what will actually run
            plans = {k: replace(p, impl="jnp") for k, p in plans.items()}
        plans["tape"] = dispatch.tape_plan(
            kind, a_shape, taps[key].shape,
            "stream" if key in stream_keys else tape_pol[key],
            itemsize=taps[key].dtype.itemsize)
        report[key] = plans
    return report


# --------------------------------------------------------- tape residency
def pad_batch(batch, mesh, B: int):
    """-> (batch, mask | None, B_padded).

    Pads the batch to the next multiple of the mesh's batch-shard count so
    the shard_map'd kernel path engages on non-divisible batches (instead of
    silently falling back to GSPMD over the jnp einsums). ``mask`` (B_pad,)
    f32 marks real samples; it folds into the per-sample loss SUM (zeroing
    every pad cotangent at the source) and into the clip factors (belt and
    braces — pad cotangents are exact zeros already).

    Pad rows REPEAT the last real sample via a gather rather than appending
    zeros with a concatenate: the SPMD partitioner mis-lowers an in-graph
    concat whose operand does not divide the batch axes (observed: real
    rows turn NaN once the per-sample-param constraint forces data
    sharding), and repeated real rows are also numerically safe for models
    whose loss degenerates on all-zero samples."""
    if mesh is None:
        return batch, None, B
    ba = mesh_batch_axes(mesh)
    n = 1
    for a in ba:
        n *= mesh.shape[a]
    if n <= 1 or B % n == 0:
        return batch, None, B
    B_pad = -(-B // n) * n
    idx = jnp.minimum(jnp.arange(B_pad), B - 1)
    batch = jax.tree_util.tree_map(lambda x: jnp.take(x, idx, axis=0), batch)
    mask = (jnp.arange(B_pad) < B).astype(F32)
    return batch, mask, B_pad


def resolve_tape(policy, res, tap_struct, act_struct) -> dict:
    """Per-active-tap storage decision: the ``REPRO_TAPE`` force env wins
    outright (the same knob the planner/report honor — the engine must
    agree with what kernel_report claims), then the ParamGroup ``tape``
    override, then the policy-level ``tape_policy``, with 'auto' resolved
    by the dispatch residency planner (kernels.dispatch.tape_plan)."""
    import os

    from repro.kernels import dispatch
    force = os.environ.get("REPRO_TAPE", "")
    out = {}
    for key in sorted(tap_struct):
        wpath = _tap_w(key)
        if wpath in res.frozen:
            continue
        pol = force or res.group_of[wpath].tape or policy.tape_policy
        if pol == "auto":
            _, kind, _ = parse_key(key)
            a = (act_struct[key]["a"].shape if kind == "moe"
                 else act_struct[key].shape)
            pol = dispatch.tape_plan(
                kind, a, tap_struct[key].shape,
                itemsize=tap_struct[key].dtype.itemsize).store
        out[key] = pol
    return out


def _act_dtype(struct):
    return struct["a"].dtype if isinstance(struct, dict) else struct.dtype


def _streamed_taps(res, active_taps) -> frozenset:
    """Taps whose clip unit STREAMS: the unit's norm closes over exactly this
    one tap's cotangent (single-path layer-scope units), so phases 2+3 fuse
    at the tap — norm, clip factor and weighted grad are emitted the moment
    the cotangent is produced, and nothing is book-kept between phases.

    Restricted to scope='layer' groups by design: a flat/group-scope unit
    that happens to own a single tap keeps the two-phase flow so existing
    scopes stay bitwise-identical (streaming ignores the tap's residency
    override — there is nothing to hold — which would silently change what
    a bf16/int8 ``tape`` request computes). ``REPRO_STREAM=0`` is the kill
    switch (forces two-phase everywhere; parity tests diff against it)."""
    import os
    if os.environ.get("REPRO_STREAM", "1") == "0":
        return frozenset()
    out = set()
    for key in active_taps:
        wpath = _tap_w(key)
        u = res.unit_of[wpath]
        if res.group_of[wpath].scope == "layer" \
                and res.units[u].paths == (wpath,):
            out.add(key)
    return frozenset(out)


# ------------------------------------------------------------------- BK core
def bk_clipped_sum(apply_fn, params, batch, cfg, mesh=None, rng=None):
    """Phases 1-3 of BK: the pre-noise clipped gradient SUM (flat dict),
    with managed tape residency.

    ``cfg`` is a DPConfig or PrivacyPolicy; each clipping unit of the
    resolved policy gets its own per-sample norm accumulator and clip factor
    C_i^(u), frozen-group taps/params are skipped outright (no cotangent is
    even requested — XLA never builds their book-keeping), and their grads
    come back as zeros.

    The backward is STREAMED, not hoarded: phase 1 linearizes the forward
    once and runs ONE transposed sweep for the cotangents; each tap's
    cotangent is consumed by its phase-2 norm as it is produced and then
    HELD per the tap's residency policy (``tape_policy`` / per-group
    ``tape``) — native (today's bitwise path), bf16/int8 compressed
    (runtime.compression stochastic rounding; norms stay fp32), or not at
    all ('recompute': NOTHING survives phase 2 for the tap — phase 3
    re-derives its weighted gradient with a reweighted-loss backward,
    one fresh forward + backward per chunk, rematting at the models' own
    jax.checkpoint scan-block boundaries; the extra forward is the
    ghost-clipping cost, see the phase-3 comment for why a residual-
    reusing transpose is worse). Each recompute chunk's backward is seeded
    through an optimization barrier carrying the clip factors, so phase
    2's cotangents are dead before any re-derivation runs. ``rng`` keys
    int8 stochastic rounding (path-stable folds; a fixed key when
    omitted).

    This is the accumulation unit for the physical/logical batch split
    (paper footnote 2): sum over microbatches, then noise ONCE per logical
    batch. Returns (flat_sums, aux).

    Under ``mesh`` the whole per-sample pipeline stays batch-sharded:
    per-sample vector-param broadcasts, squared-norm accumulators, clip
    factors and losses all live at B_local per device; fused kernels run
    shard_map'd on their local slice, and each weighted gradient pays
    exactly one psum across the batch axes. Batches that do NOT divide the
    batch-shard count are padded with masked samples (``pad_batch``) so the
    kernel path still engages."""
    from repro.core.noise import _path_rng
    policy = as_policy(cfg)
    assert policy.mode in BK_MODES, policy.mode
    B_real = batch_size_of(batch)
    batch, mask, B = pad_batch(batch, mesh, B_real)
    shard = batch_shard(mesh, B)
    ba = shard[0] if shard else ()
    flat_params = flatten(params)
    tap_struct, act_struct = tap_act_structs(apply_fn, params, batch)
    _, psp_paths = split_param_paths(params, tap_struct)
    res = resolve_policy(policy, flat_params)

    active_taps = sorted(k for k in tap_struct if _tap_w(k) not in res.frozen)
    psp_active = [p for p in psp_paths if p not in res.frozen]
    tape_pol = resolve_tape(policy, res,
                            {k: tap_struct[k] for k in active_taps},
                            act_struct)
    stream_keys = _streamed_taps(res, active_taps)
    # the activation-tape side resolves PER TAP (REPRO_TAPE force > group
    # ``tape`` override > policy default): records happen inside scan bodies
    # where keys are scope-relative, so the resolver receives the MERGED key
    # (tape._SCOPE_PREFIX) and maps it to its owning group's store
    import os
    _force_tape = os.environ.get("REPRO_TAPE", "")

    def _act_store_for(full_key: str) -> str:
        g = res.group_of.get(_tap_w(full_key))
        pol = _force_tape or (g.tape if g is not None else "") \
            or policy.tape_policy
        # recompute/auto keep acts native — they ARE the standard tape
        return "native" if pol in ("recompute", "auto") else pol

    act_stores = {k: _act_store_for(k) for k in active_taps}
    srng = None
    if any(v == "int8" for v in act_stores.values()) \
            or any(p == "int8" for p in tape_pol.values()):
        srng = rng if rng is not None else jax.random.PRNGKey(0)
    taps0 = {k: jnp.zeros(tap_struct[k].shape, tap_struct[k].dtype)
             for k in active_taps}
    psp0 = {p: jnp.broadcast_to(flat_params[p], (B,) + flat_params[p].shape)
            for p in psp_active}
    if shard:
        # pin the per-sample broadcasts batch-sharded so the transpose's psp
        # cotangents (true per-sample grads, B x param size) never
        # materialize replicated
        psp0 = {p: _constrain(v, mesh, _bspec(v.ndim, 0, ba))
                for p, v in psp0.items()}

    # ---- phase 1: one forward, linearized once; ONE transposed sweep for
    # the cotangents (with every tap at 'native' this is exactly the
    # monolithic jax.vjp — bitwise). The activation tape is stored in its
    # residency representation AT RECORD TIME (tape.act_storage): inside the
    # models' scan bodies, so the stacked native ys never materialize.
    # 'recompute' keeps acts native — that IS the standard activation tape
    # the paper's memory claim is measured against.
    from repro.core.tape import act_storage
    act_rng = (_path_rng(srng, "acts")
               if any(v == "int8" for v in act_stores.values()) else None)

    def run(taps, psp):
        merged = dict(flat_params)
        merged.update(psp)
        tape = Tape(taps)
        with act_storage(_act_store_for, act_rng):
            losses = apply_fn(unflatten(merged), batch, tape)
        lsum = jnp.sum(losses * mask) if mask is not None else jnp.sum(losses)
        return lsum, (losses, tape.acts)

    loss_sum, jvp_fn, (losses, stored_acts) = jax.linearize(
        run, taps0, psp0, has_aux=True)
    transpose = jax.linear_transpose(lambda dt, dp: jvp_fn(dt, dp),
                                     taps0, psp0)
    ds_taps, g_psp = transpose(jnp.ones_like(loss_sum))

    # ---- phase 2: per-unit per-sample norms + clip factors; each cotangent
    # is consumed by its norm as produced, then held per its tape policy.
    # STREAMED taps (single-tap layer-scope units) never hold anything:
    # their unit's clip decision closes over this one cotangent, so the
    # norm, the clip factor AND the phase-3 weighted grad all fire here —
    # one fused kernel launch where the dispatch cost model says the
    # per-sample grad fits VMEM, the composed norm+grad paths otherwise —
    # and the record is dead the moment the grad is emitted. ----
    from repro.kernels import dispatch
    unit_of = lambda p: res.unit_of[p]
    sq = [jnp.zeros((B,), F32) for _ in res.units]
    held, cache, acts_l, flat_grads = {}, {}, {}, {}
    for key in active_taps:
        wpath = _tap_w(key)
        pol = tape_pol[key]
        # bf16 records feed the consumers AS STORED: every norm/grad path
        # (fused kernels and the jnp einsums alike) upcasts per block with
        # f32 accumulation, so a wholesale dequant would only materialize
        # f32 copies of the book-kept state it exists to shrink. int8 needs
        # the (elementwise, consumer-fused) dequant.
        act = (stored_acts[key] if act_stores[key] == "bf16"
               else load_record(stored_acts[key],
                                _act_dtype(act_struct[key])))
        if key in stream_keys:
            u = unit_of(wpath)
            unit = res.units[u]
            ds, w = ds_taps[key], flat_params[wpath]
            _, kind, _ = parse_key(key)
            wv = mask if mask is not None else jnp.ones((B,), F32)
            n_ = shard[1] if shard else 1
            fplan = None
            if policy.use_kernels and kind == "mm" \
                    and not isinstance(act, dict):
                bdim = act.ndim - 3
                fplan = dispatch.fused_plan(
                    "mm", _local(act.shape, bdim, n_),
                    _local(ds.shape, bdim, n_), policy.mode,
                    res.method_for(wpath))
            if fplan is not None and fplan.method == "fused" \
                    and fplan.impl == "kernel":
                from repro.kernels import ops as kops
                fused = lambda a, d, v: kops.fused_clip_grad_mm(
                    a, d, v, unit.clipping, unit.R, unit.gamma)
                if shard:
                    # NOT _shard_call: only the grad psums across the batch
                    # axes — the per-sample sq norms stay batch-sharded
                    from jax.experimental.shard_map import shard_map
                    bdim = act.ndim - 3
                    body = lambda a, d, v: (
                        (lambda g_s: (jax.lax.psum(g_s[0], ba), g_s[1]))
                        (fused(a, d, v)))
                    G, sqk = shard_map(
                        body, mesh=mesh,
                        in_specs=(_bspec(act.ndim, bdim, ba),
                                  _bspec(ds.ndim, bdim, ba), P(ba)),
                        out_specs=(P(), P(ba)),
                        check_rep=False)(act, ds, wv)
                else:
                    G, sqk = fused(act, ds, wv)
                flat_grads[wpath] = G.astype(w.dtype)
                sq[u] = sq[u] + sqk
            else:
                # composed streaming: op-identical to the two-phase flow for
                # this unit (norm -> constrain -> sqrt -> clip -> mask ->
                # weighted grad), just with nothing held in between
                nk, cached = record_sq_norm(key, act, ds, policy.mode,
                                            policy.use_kernels,
                                            res.method_for(wpath), mesh=mesh,
                                            shard=shard, allow_cache=True)
                s = sq[u] + nk
                if shard:
                    s = _constrain(s, mesh, P(ba))
                sq[u] = s
                C_u = unit.clip_fn()(jnp.sqrt(s)).astype(F32)
                if mask is not None:
                    C_u = C_u * mask
                vocab = w.shape[-2] if kind == "emb" else 0
                flat_grads[wpath] = record_weighted_grad(
                    key, act, ds, C_u, cached, policy.use_kernels, w.dtype,
                    vocab, mesh=mesh, shard=shard)
            continue
        acts_l[key] = act
        nk, cached = record_sq_norm(key, acts_l[key], ds_taps[key],
                                    policy.mode, policy.use_kernels,
                                    res.method_for(wpath), mesh=mesh,
                                    shard=shard,
                                    allow_cache=(pol == "native"))
        cache[key] = cached
        held[key] = (None if pol == "recompute" else
                     store_record(ds_taps[key], pol,
                                  _path_rng(srng, key + "/ds")
                                  if pol == "int8" else None))
        u = unit_of(wpath)
        sq[u] = sq[u] + nk
    for p in psp_active:
        g = g_psp[p].astype(F32)
        u = unit_of(p)
        sq[u] = sq[u] + jnp.sum(g * g, axis=tuple(range(1, g.ndim)))
    if shard:
        # the (B,) accumulators (and the clip factors derived from them)
        # reduce locally at size B_local and STAY sharded into phase 3
        sq = [_constrain(s, mesh, P(ba)) for s in sq]
    unit_norms, unit_C = unit_clip_factors(res, sq)
    if mask is not None:
        unit_C = [c * mask for c in unit_C]

    # ---- phase 3: weighted gradients ----------------------------------------
    def wgrad(key, ds):
        path, kind, _ = parse_key(key)
        wpath = path + "/w"
        w = flat_params[wpath]
        vocab = w.shape[-2] if kind == "emb" else 0
        return record_weighted_grad(
            key, acts_l[key], ds, unit_C[unit_of(wpath)], cache[key],
            policy.use_kernels, w.dtype, vocab, mesh=mesh, shard=shard)

    # streamed keys are absent from ``held``/``cache``: their grads landed
    # in flat_grads during phase 2 and nothing of theirs survives to here
    rec_keys = [k for k in active_taps
                if k not in stream_keys and held[k] is None]
    for key in active_taps:
        if held.get(key) is not None:
            ds_in = (held[key] if tape_pol[key] == "bf16"
                     else load_record(held[key], tap_struct[key].dtype))
            flat_grads[_tap_w(key)] = wgrad(key, ds_in)
    if rec_keys:
        # 'recompute' taps re-derive their weighted gradients with a
        # REWEIGHTED-LOSS backward (the paper's module 2b'): for clip unit u,
        # grad_w sum_i C_i^(u) L_i == sum_i C_i^(u) g_i[w] — one standard
        # backward w.r.t. the chunk's ghost weights only, with the batch
        # re-run through an UNTAPPED, non-collecting Tape. Nothing from
        # phase 1 survives for these taps: their cotangents died at the
        # norms, their activation records are never consumed in phase 3,
        # and the re-derivation backward remats at the models' own
        # jax.checkpoint scan-block boundaries. (A per-chunk tap-cotangent
        # transpose was measured strictly worse: its zero tangents for
        # every other tap materialize as full-size scan inputs.)
        token = unit_C[0]
        for u in range(len(res.units)):
            rec_u = [k for k in rec_keys if unit_of(_tap_w(k)) == u]
            if not rec_u:
                continue
            nch = max(1, min(int(policy.tape_chunks), len(rec_u)))
            size = -(-len(rec_u) // nch)
            C_u = jax.lax.stop_gradient(unit_C[u])
            for lo in range(0, len(rec_u), size):
                group = rec_u[lo:lo + size]
                wpaths = [_tap_w(k) for k in group]

                def reweighted(wsub):
                    merged = dict(flat_params)
                    merged.update(psp0)
                    merged.update(wsub)
                    losses = apply_fn(unflatten(merged), batch,
                                      Tape({}, collect=False))
                    return jnp.sum(losses * C_u)

                # the backward's cotangent seed goes through an optimization
                # barrier CHAINED on the previous chunk's grads (the clip
                # factors for the first): phase 2 completes — its cotangents
                # freed — before any re-derivation runs, and the sweeps run
                # one at a time so their live sets never overlap
                seed, _ = jax.lax.optimization_barrier(
                    (jnp.ones_like(loss_sum), token))
                _, vjp_w = jax.vjp(reweighted,
                                   {p: flat_params[p] for p in wpaths})
                (gw,) = vjp_w(seed)
                for p in wpaths:
                    flat_grads[p] = gw[p].astype(flat_params[p].dtype)
                token = flat_grads[wpaths[-1]]
    for p in psp_active:
        g = g_psp[p]
        flat_grads[p] = jnp.einsum("b...,b->...", g.astype(F32),
                                   unit_C[unit_of(p)]).astype(
                                       flat_params[p].dtype)
    for p in res.frozen:
        flat_grads[p] = jnp.zeros_like(flat_params[p])

    if mask is not None:   # observability reports REAL samples only
        losses = losses[:B_real]
        sq = [s[:B_real] for s in sq]
        unit_norms = [n[:B_real] for n in unit_norms]
        unit_C = [c[:B_real] for c in unit_C]
    return flat_grads, norm_aux(res, losses, sq, unit_norms, unit_C)


def monolithic_clipped_sum(apply_fn, params, batch, cfg, mesh=None):
    """The pre-residency reference: ONE jax.vjp whose tap cotangents all
    stay live from phase 1 through phase 3. Kept as the parity oracle the
    streamed engine is tested against (tape_policy='native' must match it
    bitwise; 'recompute'/'bf16'/'int8' within documented tolerances) — not
    wired to any production path."""
    policy = as_policy(cfg)
    assert policy.mode in BK_MODES, policy.mode
    B = batch_size_of(batch)
    shard = batch_shard(mesh, B)
    ba = shard[0] if shard else ()
    flat_params = flatten(params)
    tap_struct = tap_structs(apply_fn, params, batch)
    _, psp_paths = split_param_paths(params, tap_struct)
    res = resolve_policy(policy, flat_params)

    active_taps = sorted(k for k in tap_struct if _tap_w(k) not in res.frozen)
    psp_active = [p for p in psp_paths if p not in res.frozen]
    taps0 = {k: jnp.zeros(tap_struct[k].shape, tap_struct[k].dtype)
             for k in active_taps}
    psp0 = {p: jnp.broadcast_to(flat_params[p], (B,) + flat_params[p].shape)
            for p in psp_active}
    if shard:
        psp0 = {p: _constrain(v, mesh, _bspec(v.ndim, 0, ba))
                for p, v in psp0.items()}

    def run(taps, psp):
        merged = dict(flat_params)
        merged.update(psp)
        tape = Tape(taps)
        losses = apply_fn(unflatten(merged), batch, tape)
        return jnp.sum(losses), (losses, tape.acts)

    loss_sum, vjp_fn, (losses, acts) = jax.vjp(run, taps0, psp0, has_aux=True)
    ds_taps, g_psp = vjp_fn(jnp.ones_like(loss_sum))

    unit_of = lambda p: res.unit_of[p]
    sq = [jnp.zeros((B,), F32) for _ in res.units]
    cache = {}
    for key in active_taps:
        wpath = _tap_w(key)
        nk, cached = record_sq_norm(key, acts[key], ds_taps[key], policy.mode,
                                    policy.use_kernels,
                                    res.method_for(wpath), mesh=mesh,
                                    shard=shard)
        cache[key] = cached
        u = unit_of(wpath)
        sq[u] = sq[u] + nk
    for p in psp_active:
        g = g_psp[p].astype(F32)
        u = unit_of(p)
        sq[u] = sq[u] + jnp.sum(g * g, axis=tuple(range(1, g.ndim)))
    if shard:
        sq = [_constrain(s, mesh, P(ba)) for s in sq]
    unit_norms, unit_C = unit_clip_factors(res, sq)

    flat_grads = {}
    for key in active_taps:
        path, kind, _ = parse_key(key)
        wpath = path + "/w"
        w = flat_params[wpath]
        vocab = w.shape[-2] if kind == "emb" else 0
        flat_grads[wpath] = record_weighted_grad(
            key, acts[key], ds_taps[key], unit_C[unit_of(wpath)], cache[key],
            policy.use_kernels, w.dtype, vocab, mesh=mesh, shard=shard)
    for p in psp_active:
        g = g_psp[p]
        flat_grads[p] = jnp.einsum("b...,b->...", g.astype(F32),
                                   unit_C[unit_of(p)]).astype(
                                       flat_params[p].dtype)
    for p in res.frozen:
        flat_grads[p] = jnp.zeros_like(flat_params[p])

    return flat_grads, norm_aux(res, losses, sq, unit_norms, unit_C)


def bk_private_grad(apply_fn, params, batch, rng, cfg, step=None, mesh=None,
                    pspecs=None):
    """Private gradient via Book-Keeping: clipped sum + noise + 1/B scale.
    ``step`` feeds stateful noise mechanisms (tree aggregation raises when it
    is omitted); the default Gaussian ignores it. ``mesh``/``pspecs`` lower
    the clipped sum batch-sharded and draw phase-4 noise shard-local.
    Returns (grads matching the params tree, aux)."""
    policy = as_policy(cfg)
    B = batch_size_of(batch)
    flat_sums, aux = bk_clipped_sum(apply_fn, params, batch, policy,
                                    mesh=mesh, rng=rng)
    # ---- phase 4: noise (sigma * sigma_scale_u * composed S per unit) + scale
    res = resolve_policy(policy, flatten(params))
    flat_grads = finalize_noise(policy, res, flat_sums, rng, float(B), step,
                                mesh=mesh, pspecs=pspecs)
    return unflatten(flat_grads), aux
