"""PrivacyPolicy: the per-parameter-group DP API.

A policy is an ordered list of :class:`ParamGroup` rules matched against the
flattened param tree (first match wins). Each group carries its own clipping
fn + threshold R, a norm *scope*, an optional ghost-vs-direct override for
``kernels.dispatch``, a noise scale, and a trainable flag:

  scope='flat'   the group joins the shared flat pool: ONE per-sample norm
                 over every flat-scope param, one clip factor (classic
                 Abadi-style clipping; all flat groups must agree on
                 clipping/R/gamma/sigma_scale).
  scope='group'  the group is its own clipping unit: its own per-sample norm
                 ||g_i^(g)||, its own C_i^(g) = clip(||g_i^(g)||; R_g)
                 (group-wise clipping, He et al. 2022 / Bu et al. 2023).
  scope='layer'  EVERY trainable param path the group matches becomes its
                 own clipping unit (per-layer clipping, the finest grain of
                 He et al. 2022). Each unit's norm closes over a single
                 tap's cotangent, so the BK engine can STREAM it: the norm,
                 the clip factor and the weighted grad are all emitted the
                 moment that tap's cotangent is produced, and nothing is
                 book-kept between phases 2 and 3 (core.bk streamed fast
                 path). Note that under a scanned trunk a path is the
                 STACKED op (e.g. ``blocks/attn/qkv/w`` over all scan
                 layers) — one unit per op type, pooled over scan depth.
  sigma_scale    heterogeneous per-group noise: the noise std on this
                 group's coordinates is sigma * sigma_scale * S where S is
                 the composed sensitivity below. The default 1.0 reproduces
                 the flat scheme (every coordinate at sigma * S) exactly;
                 scale < 1 under-noises a group relative to flat — e.g.
                 sigma_scale = R_g / S gives noise proportional to the
                 group's OWN sensitivity. Accounting must then compose the
                 per-group Gaussian curves jointly
                 (``accounting.compute_epsilon`` with
                 ``ResolvedPolicy.noise_multipliers()``) — the single-sigma
                 SGM bound no longer applies.
  trainable=False
                 the LoRA fast path: the group's params are closed over as
                 constants — no tap differentiation, no norm, no weighted
                 grad, no noise; grads come back as zeros.

The L2 sensitivity of one sample's clipped contribution composes as
sqrt(R_flat^2 + sum_g R_g^2 + sum_l R_l^2) over the non-empty trainable
units — layer-scope groups contribute one R_l term PER MEMBER PATH
(``accounting.compose_sensitivity``); the noise mechanism scales each
group's leaves by sigma_scale_g times that.

A bare :class:`repro.core.bk.DPConfig` lowers to a single-group flat policy
via :func:`as_policy`, so every pre-policy call site runs unchanged.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp

from repro.core.accounting import compose_sensitivity
from repro.core.clipping import get_clip_fn
from repro.core.tape import TAPE_POLICIES

SCOPES = ("flat", "group", "layer")
METHODS = ("", "ghost", "direct")
TAPES = ("",) + TAPE_POLICIES


@dataclass(frozen=True)
class ParamGroup:
    """One ordered matching rule over flattened param paths."""
    name: str
    match: str                       # path prefix, or regex (fullmatch)
    clipping: str = "automatic"      # clipping fn name (core.clipping)
    R: float = 1.0                   # per-group clipping threshold R_g
    scope: str = "flat"              # 'flat' | 'group' | 'layer' (norm scope)
    gamma: float = 0.01              # automatic-clipping stability constant
    trainable: bool = True           # False = frozen (no taps / grads / noise)
    method: str = ""                 # '' | 'ghost' | 'direct' dispatch override
    sigma_scale: float = 1.0         # noise std multiplier vs the flat scheme
    tape: str = ""                   # tape residency override for this
                                     # group's taps ('' = policy default;
                                     # core.tape.TAPE_POLICIES)

    def __post_init__(self):
        if self.scope not in SCOPES:
            raise ValueError(f"group {self.name!r}: scope must be one of "
                             f"{SCOPES}, got {self.scope!r}")
        if self.method not in METHODS:
            raise ValueError(f"group {self.name!r}: method must be one of "
                             f"{METHODS}, got {self.method!r}")
        if self.tape not in TAPES:
            raise ValueError(f"group {self.name!r}: tape must be one of "
                             f"{TAPES}, got {self.tape!r}")
        if self.sigma_scale <= 0.0:
            raise ValueError(f"group {self.name!r}: sigma_scale must be > 0 "
                             f"(got {self.sigma_scale}); use trainable=False "
                             "to exempt params from noise")

    def matches(self, path: str) -> bool:
        if path == self.match or path.startswith(self.match + "/"):
            return True
        try:
            return re.fullmatch(self.match, path) is not None
        except re.error:
            return False

    def clip_fn(self) -> Callable:
        kw = {"gamma": self.gamma} if self.clipping == "automatic" else {}
        return get_clip_fn(self.clipping, self.R, **kw)


@dataclass(frozen=True)
class PrivacyPolicy:
    """Ordered ParamGroup rules + the engine-level knobs DPConfig used to own."""
    groups: tuple                    # tuple[ParamGroup, ...], first match wins
    mode: str = "bk"                 # implementation (BK_MODES + baselines)
    sigma: float = 0.0               # noise multiplier (0 = clipping only)
    noise: str = "gaussian"          # NoiseMechanism name (core.noise)
    noise_seed: int = 0              # node-noise seed for stateful mechanisms
    noise_depth: int = 0             # tree depth (0 = mechanism default; set
                                     # ceil(log2(steps+1)) to cut draw cost)
    noise_restart_every: int = 0     # tree epoch restarts, in steps (0 = off;
                                     # key it off the FTRL optimizer's
                                     # restart_every so both reset together)
    noise_completion: bool = False   # honest-restart (Honaker) completion
    use_kernels: bool = True         # fused Pallas kernels via kernels.dispatch
    tape_policy: str = "native"      # default tape residency for every tap
                                     # (core.tape.TAPE_POLICIES; 'auto' lets
                                     # the dispatch planner pick per tap)
    tape_chunks: int = 1             # phase-3 re-derivation chunk count for
                                     # 'recompute' taps (each chunk is one
                                     # backward sweep; its cotangents die
                                     # before the next chunk's sweep runs)

    def __post_init__(self):
        if not self.groups:
            raise ValueError("policy needs at least one ParamGroup")
        if self.tape_policy not in TAPE_POLICIES:
            raise ValueError(f"tape_policy must be one of {TAPE_POLICIES}, "
                             f"got {self.tape_policy!r}")
        if self.tape_chunks < 1:
            raise ValueError(f"tape_chunks must be >= 1 "
                             f"(got {self.tape_chunks})")
        names = [g.name for g in self.groups]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate group names: {names}")
        if (self.noise_restart_every or self.noise_completion) \
                and self.noise != "tree":
            # GaussianMechanism would silently ignore both knobs — per-step
            # independent noise has no tree to restart or complete
            raise ValueError(
                "noise_restart_every/noise_completion require noise='tree' "
                f"(got noise={self.noise!r})")
        if self.noise_completion and self.noise_restart_every <= 0:
            # fail at config time, not at the first training-step trace
            raise ValueError(
                "noise_completion corrects the noise at epoch boundaries — "
                "set noise_restart_every > 0 (the optimizer's restart "
                "period) alongside it")

    def mechanism(self):
        from repro.core.noise import get_mechanism
        return get_mechanism(self.noise, seed=self.noise_seed,
                             depth=self.noise_depth,
                             restart_every=self.noise_restart_every,
                             completion=self.noise_completion)

    def group_for(self, path: str) -> ParamGroup:
        for g in self.groups:
            if g.matches(path):
                return g
        raise ValueError(f"param {path!r} matched no policy group")


def as_policy(cfg) -> PrivacyPolicy:
    """DPConfig -> equivalent single-group flat policy; policies pass through."""
    if isinstance(cfg, PrivacyPolicy):
        return cfg
    return PrivacyPolicy(
        groups=(ParamGroup("all", ".*", clipping=cfg.clipping, R=cfg.R,
                           scope="flat", gamma=cfg.gamma),),
        mode=cfg.mode, sigma=cfg.sigma,
        use_kernels=cfg.use_kernels,
        tape_policy=cfg.tape_policy, tape_chunks=cfg.tape_chunks)


def with_scope(cfg, scope: str) -> PrivacyPolicy:
    """Re-scope a DPConfig / PrivacyPolicy: every TRAINABLE group's norm
    scope becomes ``scope`` (frozen groups are untouched — they have no
    norm). The ``--clipping-scope`` CLI knob and the per-scope benchmark
    cells route here. Each group keeps its own clipping/R/gamma/sigma_scale;
    note that re-scoping a heterogeneous preset to 'flat' raises at
    resolve time (flat groups must share one norm pool's parameters)."""
    import dataclasses
    policy = as_policy(cfg)
    if not scope:
        return policy
    if scope not in SCOPES:
        raise ValueError(f"clipping scope must be one of {SCOPES}, "
                         f"got {scope!r}")
    groups = tuple(dataclasses.replace(g, scope=scope) if g.trainable else g
                   for g in policy.groups)
    return dataclasses.replace(policy, groups=groups)


# ------------------------------------------------------------------ resolution
@dataclass(frozen=True)
class ClipUnit:
    """One clipping unit: a per-sample norm accumulator + clip factor C_i."""
    name: str
    clipping: str
    R: float
    gamma: float
    paths: tuple                     # member param paths (sorted)
    sigma_scale: float = 1.0         # noise std multiplier vs the flat scheme

    def clip_fn(self) -> Callable:
        kw = {"gamma": self.gamma} if self.clipping == "automatic" else {}
        return get_clip_fn(self.clipping, self.R, **kw)


@dataclass(frozen=True)
class ResolvedPolicy:
    """A policy bound to a concrete param tree (pure-python, config time)."""
    policy: PrivacyPolicy
    units: tuple                     # tuple[ClipUnit, ...]
    unit_of: dict                    # path -> unit index (trainable paths only)
    group_of: dict                   # path -> ParamGroup (every path)
    frozen: frozenset                # paths of non-trainable groups
    sensitivity: float               # sqrt(sum_u R_u^2) over non-empty units

    def method_for(self, path: str) -> str:
        return self.group_of[path].method

    @property
    def heterogeneous(self) -> bool:
        return any(u.sigma_scale != 1.0 for u in self.units)

    def noise_scales(self) -> dict:
        """Per-trainable-path noise std multiplier on sigma:
        sigma_scale_u * composed sensitivity. All scales 1.0 (the default)
        reproduces the flat scheme's sigma * S on every leaf exactly."""
        return {p: self.units[u].sigma_scale * self.sensitivity
                for p, u in self.unit_of.items()}

    def noise_multipliers(self) -> list:
        """Per-unit effective Gaussian noise multipliers relative to each
        unit's OWN sensitivity R_u — the quantity privacy accounting
        composes (feed to ``accounting.compute_epsilon`` as a sequence).
        With every sigma_scale at 1.0 the joint bound coincides with the
        flat single-sigma SGM bound."""
        sigma = self.policy.sigma
        return [sigma * u.sigma_scale * self.sensitivity / u.R
                for u in self.units]


def resolve_policy(policy: PrivacyPolicy, param_paths) -> ResolvedPolicy:
    """Bind a policy to the flattened param paths.

    The ordered groups must form a true partition: every path matches at
    least one group (first match claims it); unmatched paths raise.
    """
    param_paths = sorted(param_paths)
    group_of, members = {}, {g.name: [] for g in policy.groups}
    unmatched = []
    for path in param_paths:
        for g in policy.groups:
            if g.matches(path):
                group_of[path] = g
                members[g.name].append(path)
                break
        else:
            unmatched.append(path)
    if unmatched:
        raise ValueError(
            "params matched no policy group (add a catch-all rule such as "
            f"ParamGroup('rest', '.*')): {unmatched}")

    flat_groups = [g for g in policy.groups
                   if g.trainable and g.scope == "flat" and members[g.name]]
    for g in flat_groups[1:]:
        ref = flat_groups[0]
        if (g.clipping, g.R, g.gamma, g.sigma_scale) != \
                (ref.clipping, ref.R, ref.gamma, ref.sigma_scale):
            raise ValueError(
                "flat-scope groups share ONE norm pool and so must agree on "
                f"(clipping, R, gamma, sigma_scale): {ref.name!r} has "
                f"{(ref.clipping, ref.R, ref.gamma, ref.sigma_scale)}, "
                f"{g.name!r} has "
                f"{(g.clipping, g.R, g.gamma, g.sigma_scale)}")

    units, unit_of = [], {}
    if flat_groups:
        ref = flat_groups[0]
        paths = sorted(p for g in flat_groups for p in members[g.name])
        name = ref.name if len(flat_groups) == 1 else "flat"
        units.append(ClipUnit(name, ref.clipping, ref.R, ref.gamma,
                              tuple(paths), ref.sigma_scale))
        for p in paths:
            unit_of[p] = 0
    for g in policy.groups:
        if not (g.trainable and members[g.name]):
            continue
        if g.scope == "group":
            units.append(ClipUnit(g.name, g.clipping, g.R, g.gamma,
                                  tuple(members[g.name]), g.sigma_scale))
            for p in members[g.name]:
                unit_of[p] = len(units) - 1
        elif g.scope == "layer":
            # per-layer clipping: one single-path unit per member param —
            # the unit name carries the path so group_norms / noise
            # multipliers stay addressable per layer
            for p in members[g.name]:
                units.append(ClipUnit(f"{g.name}:{p}", g.clipping, g.R,
                                      g.gamma, (p,), g.sigma_scale))
                unit_of[p] = len(units) - 1

    frozen = frozenset(p for p in param_paths if not group_of[p].trainable)
    return ResolvedPolicy(policy=policy, units=tuple(units), unit_of=unit_of,
                          group_of=group_of, frozen=frozen,
                          sensitivity=compose_sensitivity(
                              [u.R for u in units]))


def unit_clip_factors(res: ResolvedPolicy, sq):
    """Per-unit per-sample sq norms -> ([norms_u], [C_u]) — phase 2's tail,
    shared by every implementation."""
    norms = [jnp.sqrt(s) for s in sq]
    C = [unit.clip_fn()(n).astype(jnp.float32)
         for unit, n in zip(res.units, norms)]
    return norms, C


def norm_aux(res: ResolvedPolicy, losses, sq, unit_norms, unit_C) -> dict:
    """The aux dict every mode returns. ``per_sample_norms`` is the total
    norm across units; single-unit policies additionally keep the pre-policy
    ``clip_factors`` contract."""
    aux = {"loss": jnp.mean(losses),
           "per_sample_norms": (unit_norms[0] if len(res.units) == 1
                                else jnp.sqrt(sum(sq))),
           "group_norms": {u.name: n for u, n in zip(res.units, unit_norms)},
           "group_clip_factors": {u.name: c
                                  for u, c in zip(res.units, unit_C)}}
    if len(res.units) == 1:
        aux["clip_factors"] = unit_C[0]
    return aux


def finalize_noise(policy: PrivacyPolicy, res: ResolvedPolicy,
                   flat_sums: dict, rng, denom: float, step=None,
                   mesh=None, pspecs=None) -> dict:
    """Phase 4 shared by every implementation (all 8 BK/baseline modes route
    here): the policy's noise mechanism over the trainable leaves, each leaf
    scaled by its unit's sigma_scale * composed sensitivity (a homogeneous
    policy passes the bare composed sensitivity — bitwise-identical to the
    pre-heterogeneous behaviour). Frozen leaves pass through untouched (they
    are zeros). With ``mesh``/``pspecs`` (flat {path: PartitionSpec}) the
    noise is generated shard-local — each device draws only its slice."""
    active = {p: g for p, g in flat_sums.items() if p not in res.frozen}
    scales = res.noise_scales() if res.heterogeneous else res.sensitivity
    out = policy.mechanism().add(active, rng, policy.sigma, scales,
                                 denom, step=step, mesh=mesh, pspecs=pspecs)
    for p, g in flat_sums.items():
        if p in res.frozen:
            out[p] = g
    return out


def noise_leaf_fn(policy: PrivacyPolicy, res: ResolvedPolicy, rng,
                  denom: float, step=None, mesh=None, pspecs=None):
    """Per-leaf phase 4: -> fn(path, g_sum) -> private grad leaf.

    The fused noise+optimizer-update path (``Optimizer.update_leaves``)
    consumes leaves one at a time so the full noised-gradient tree is never
    materialized alongside the clipped sums — only one leaf's noise is live
    at any point in the schedule. Semantically identical to
    ``finalize_noise`` leaf-by-leaf (frozen leaves pass through)."""
    from repro.core.noise import _scale_for, _spec_of
    mech = policy.mechanism()
    scales = res.noise_scales() if res.heterogeneous else res.sensitivity

    def leaf(path: str, g):
        if path in res.frozen:
            return g
        return mech.add_leaf(path, g, rng, policy.sigma,
                             _scale_for(scales, path), denom, step=step,
                             mesh=mesh, spec=_spec_of(pspecs, path))

    return leaf
