"""Per-sample clipping factor functions C(||g_i||; R) from Eq. (1).

All return the factor C_i such that the clipped per-sample gradient is
``C_i * g_i`` and the sum has L2 sensitivity at most R.
"""
from __future__ import annotations

from functools import partial

import jax.numpy as jnp

_EPS = 1e-12


def abadi(norms: jnp.ndarray, R: float) -> jnp.ndarray:
    """Abadi et al. 2016: C_i = min(R/||g_i||, 1)."""
    return jnp.minimum(R / (norms + _EPS), 1.0)


def automatic(norms: jnp.ndarray, R: float, gamma: float = 0.01) -> jnp.ndarray:
    """Bu et al. 2022b automatic clipping: C_i = R/(||g_i|| + gamma)."""
    return R / (norms + gamma)


def normalize(norms: jnp.ndarray, R: float) -> jnp.ndarray:
    """Gradient normalization: C_i = R/||g_i||."""
    return R / (norms + _EPS)


def flat(norms: jnp.ndarray, R: float) -> jnp.ndarray:
    """Bu et al. 2021b indicator clipping: C_i = 1[||g_i|| <= R]."""
    return (norms <= R).astype(norms.dtype)


CLIP_FNS = {
    "abadi": abadi,
    "automatic": automatic,
    "normalize": normalize,
    "flat": flat,
}


def get_clip_fn(name: str, R: float, **kw):
    try:
        fn = CLIP_FNS[name]
    except KeyError:
        raise ValueError(f"unknown clipping fn {name!r}; options: {sorted(CLIP_FNS)}")
    return partial(fn, R=R, **kw)
