"""Baseline DP implementations the paper compares against (Table 2).

Every baseline computes the SAME private gradient as BK (same math, different
time/space tradeoff) — tests assert exact agreement:

  non-private   1 bwd, no clipping                            (reference point)
  TF-Privacy    B sequential backprops (lax.map)              6BTpd, slow
  Opacus        vmap per-sample grads, instantiated           8BTpd, Bpd memory
  FastGradClip  per-sample norms then 2nd bwd of reweighted   8BTpd
  GhostClip     ghost norms (taps) then 2nd full bwd          10BTpd + 2BT^2(p+d)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bk import DPConfig, batch_size_of, split_param_paths, tap_structs, record_sq_norm
from repro.core.noise import add_noise
from repro.core.tape import Tape
from repro.utils.tree import flatten, unflatten

F32 = jnp.float32


def _loss_all(apply_fn, params, batch):
    return apply_fn(params, batch, Tape(None))  # (B,) per-sample losses


def _single(apply_fn, params, sample):
    batch1 = jax.tree_util.tree_map(lambda x: x[None], sample)
    return _loss_all(apply_fn, params, batch1)[0]


def _tree_sq_norm(g):
    return sum(jnp.sum(jnp.square(x.astype(F32))) for x in jax.tree_util.tree_leaves(g))


def _clip_sum_noise(per_sample_grads, losses, rng, cfg, B):
    """Shared tail: norms -> C -> weighted sum -> noise. per_sample_grads has
    leading B on every leaf."""
    flat = flatten(per_sample_grads)
    sq = jnp.zeros((B,), F32)
    for g in flat.values():
        g = g.astype(F32)
        sq = sq + jnp.sum(g * g, axis=tuple(range(1, g.ndim)))
    norms = jnp.sqrt(sq)
    C = cfg.clip_fn()(norms).astype(F32)
    summed = {p: jnp.einsum("b...,b->...", g.astype(F32), C).astype(g.dtype)
              for p, g in flat.items()}
    summed = add_noise(summed, rng, cfg.sigma, cfg.R, float(B))
    aux = {"loss": jnp.mean(losses), "per_sample_norms": norms, "clip_factors": C}
    return unflatten(summed), aux


# ----------------------------------------------------------------- baselines
def nonprivate_grad(apply_fn, params, batch, rng, cfg: DPConfig):
    def mean_loss(p):
        return jnp.mean(_loss_all(apply_fn, p, batch))

    loss, grads = jax.value_and_grad(mean_loss)(params)
    return grads, {"loss": loss}


def opacus_grad(apply_fn, params, batch, rng, cfg: DPConfig):
    """vmap(grad) — instantiates all B per-sample gradients (module 4)."""
    B = batch_size_of(batch)
    gfn = jax.grad(lambda p, s: _single(apply_fn, p, s))
    per_g = jax.vmap(gfn, in_axes=(None, 0))(params, batch)
    losses = _loss_all(apply_fn, params, batch)
    return _clip_sum_noise(per_g, losses, rng, cfg, B)


def tfprivacy_grad(apply_fn, params, batch, rng, cfg: DPConfig):
    """B sequential backprops via lax.map (memory-light, slow)."""
    B = batch_size_of(batch)
    vg = jax.value_and_grad(lambda p, s: _single(apply_fn, p, s), argnums=0)
    losses, per_g = jax.lax.map(lambda s: vg(params, s), batch)
    return _clip_sum_noise(per_g, losses, rng, cfg, B)


def fastgradclip_grad(apply_fn, params, batch, rng, cfg: DPConfig):
    """Lee & Kifer 2020: per-sample norms (grads discarded), then a second
    backprop of the reweighted loss sum_i C_i L_i."""
    B = batch_size_of(batch)
    gfn = jax.grad(lambda p, s: _single(apply_fn, p, s))
    sq = jax.lax.map(lambda s: _tree_sq_norm(gfn(params, s)), batch)
    norms = jnp.sqrt(sq)
    C = jax.lax.stop_gradient(cfg.clip_fn()(norms).astype(F32))

    def reweighted(p):
        losses = _loss_all(apply_fn, p, batch)
        return jnp.sum(C * losses), losses

    (_, losses), grads = jax.value_and_grad(reweighted, has_aux=True)(params)
    flat = {p: g for p, g in flatten(grads).items()}
    flat = add_noise(flat, rng, cfg.sigma, cfg.R, float(B))
    aux = {"loss": jnp.mean(losses), "per_sample_norms": norms, "clip_factors": C}
    return unflatten(flat), aux


def ghostclip_grad(apply_fn, params, batch, rng, cfg: DPConfig):
    """Li et al. 2021 / Bu et al. 2022a: ghost norms from a tapped first
    backprop (no per-sample grads), then a second full backprop."""
    B = batch_size_of(batch)
    flat_params = flatten(params)
    tap_struct = tap_structs(apply_fn, params, batch)
    _, psp_paths = split_param_paths(params, tap_struct)
    taps0 = {k: jnp.zeros(v.shape, v.dtype) for k, v in tap_struct.items()}
    psp0 = {p: jnp.broadcast_to(flat_params[p], (B,) + flat_params[p].shape)
            for p in psp_paths}

    def run(taps, psp):
        merged = dict(flat_params)
        merged.update(psp)
        tape = Tape(taps)
        losses = apply_fn(unflatten(merged), batch, tape)
        return jnp.sum(losses), tape.acts

    _, vjp_fn, acts = jax.vjp(run, taps0, psp0, has_aux=True)
    ds_taps, g_psp = vjp_fn(jnp.asarray(1.0, F32))

    sq = jnp.zeros((B,), F32)
    for key in sorted(acts):
        nk, _ = record_sq_norm(key, acts[key], ds_taps[key], "bk", cfg.use_kernels)
        sq = sq + nk
    for p in psp_paths:
        g = g_psp[p].astype(F32)
        sq = sq + jnp.sum(g * g, axis=tuple(range(1, g.ndim)))
    norms = jnp.sqrt(sq)
    C = jax.lax.stop_gradient(cfg.clip_fn()(norms).astype(F32))

    def reweighted(p):
        losses = _loss_all(apply_fn, p, batch)
        return jnp.sum(C * losses), losses

    (_, losses), grads = jax.value_and_grad(reweighted, has_aux=True)(params)
    flat = flatten(grads)
    flat = add_noise(flat, rng, cfg.sigma, cfg.R, float(B))
    aux = {"loss": jnp.mean(losses), "per_sample_norms": norms, "clip_factors": C}
    return unflatten(flat), aux
