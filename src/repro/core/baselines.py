"""Baseline DP implementations the paper compares against (Table 2).

Every baseline computes the SAME private gradient as BK (same math, different
time/space tradeoff) — tests assert exact agreement — and every baseline
honors the full PrivacyPolicy semantics (per-group clipping units, frozen
groups, pluggable noise), so policy tests can use them as references:

  non-private   1 bwd, no clipping                            (reference point)
  TF-Privacy    B sequential backprops (lax.map)              6BTpd, slow
  Opacus        vmap per-sample grads, instantiated           8BTpd, Bpd memory
  FastGradClip  per-sample norms then 2nd bwd of reweighted   8BTpd
  GhostClip     ghost norms (taps) then 2nd full bwd          10BTpd + 2BT^2(p+d)

Group-wise clipping gives each clip unit its own factor C_i^(u), so the
"reweighted loss" trick of FastGradClip/GhostClip (one backward of
sum_i C_i L_i) generalizes to one VJP of the per-sample loss VECTOR per unit
with cotangent C^(u) — still no per-sample weight gradients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bk import (batch_size_of, record_sq_norm, split_param_paths,
                           tap_structs)
from repro.core.policy import (as_policy, finalize_noise, norm_aux,
                               resolve_policy, unit_clip_factors)
from repro.core.tape import Tape, parse_key
from repro.utils.tree import flatten, unflatten

F32 = jnp.float32


def _loss_all(apply_fn, params, batch):
    return apply_fn(params, batch, Tape(None))  # (B,) per-sample losses


def _single(apply_fn, params, sample):
    batch1 = jax.tree_util.tree_map(lambda x: x[None], sample)
    return _loss_all(apply_fn, params, batch1)[0]


def _unit_sq_norms(flat_grads, res, B, leading_batch: bool):
    """Per-clip-unit per-sample (or scalar) squared norms from a flat grad
    dict; frozen leaves are excluded."""
    shape = (B,) if leading_batch else ()
    sq = [jnp.zeros(shape, F32) for _ in res.units]
    for p, g in flat_grads.items():
        if p in res.frozen:
            continue
        g = g.astype(F32)
        axes = tuple(range(1, g.ndim)) if leading_batch else None
        u = res.unit_of[p]
        sq[u] = sq[u] + jnp.sum(g * g, axis=axes)
    return sq


def _clip_sum_noise(per_sample_grads, losses, rng, policy, params, B, step,
                    mesh=None, pspecs=None):
    """Shared tail: per-unit norms -> C^(u) -> weighted sum -> noise.
    per_sample_grads has leading B on every leaf."""
    res = resolve_policy(policy, flatten(params))
    flat = flatten(per_sample_grads)
    sq = _unit_sq_norms(flat, res, B, leading_batch=True)
    unit_norms, unit_C = unit_clip_factors(res, sq)
    summed = {}
    for p, g in flat.items():
        if p in res.frozen:
            summed[p] = jnp.zeros(g.shape[1:], g.dtype)
        else:
            summed[p] = jnp.einsum("b...,b->...", g.astype(F32),
                                   unit_C[res.unit_of[p]]).astype(g.dtype)
    summed = finalize_noise(policy, res, summed, rng, float(B), step,
                            mesh=mesh, pspecs=pspecs)
    return unflatten(summed), norm_aux(res, losses, sq, unit_norms, unit_C)


def _unit_weighted_grads(apply_fn, params, batch, res, unit_C):
    """sum_i C_i^(u(p)) g_i[p] for every param, WITHOUT per-sample grads:
    one VJP of the per-sample loss vector per clip unit (cotangent C^(u)),
    then select each unit's own leaves. Frozen leaves come back zero."""
    losses, vjp_fn = jax.vjp(lambda p: _loss_all(apply_fn, p, batch), params)
    flat_params = flatten(params)
    flat_out = {p: jnp.zeros_like(v) for p, v in flat_params.items()}
    for u, (unit, C) in enumerate(zip(res.units, unit_C)):
        (g_u,) = vjp_fn(jax.lax.stop_gradient(C).astype(losses.dtype))
        fg = flatten(g_u)
        for p in unit.paths:
            flat_out[p] = fg[p]
    return losses, flat_out


# ----------------------------------------------------------------- baselines
def nonprivate_grad(apply_fn, params, batch, rng, cfg, step=None,
                    mesh=None, pspecs=None):
    policy = as_policy(cfg)
    res = resolve_policy(policy, flatten(params))

    def mean_loss(p):
        return jnp.mean(_loss_all(apply_fn, p, batch))

    loss, grads = jax.value_and_grad(mean_loss)(params)
    if res.frozen:  # policies freeze groups even without clipping/noise
        flat = flatten(grads)
        for p in res.frozen:
            flat[p] = jnp.zeros_like(flat[p])
        grads = unflatten(flat)
    return grads, {"loss": loss}


def opacus_grad(apply_fn, params, batch, rng, cfg, step=None,
                mesh=None, pspecs=None):
    """vmap(grad) — instantiates all B per-sample gradients (module 4)."""
    policy = as_policy(cfg)
    B = batch_size_of(batch)
    gfn = jax.grad(lambda p, s: _single(apply_fn, p, s))
    per_g = jax.vmap(gfn, in_axes=(None, 0))(params, batch)
    losses = _loss_all(apply_fn, params, batch)
    return _clip_sum_noise(per_g, losses, rng, policy, params, B, step,
                           mesh=mesh, pspecs=pspecs)


def tfprivacy_grad(apply_fn, params, batch, rng, cfg, step=None,
                   mesh=None, pspecs=None):
    """B sequential backprops via lax.map (memory-light, slow)."""
    policy = as_policy(cfg)
    B = batch_size_of(batch)
    vg = jax.value_and_grad(lambda p, s: _single(apply_fn, p, s), argnums=0)
    losses, per_g = jax.lax.map(lambda s: vg(params, s), batch)
    return _clip_sum_noise(per_g, losses, rng, policy, params, B, step,
                           mesh=mesh, pspecs=pspecs)


def fastgradclip_grad(apply_fn, params, batch, rng, cfg, step=None,
                      mesh=None, pspecs=None):
    """Lee & Kifer 2020: per-sample norms (grads discarded), then a second
    backprop of the reweighted loss — one VJP per clip unit."""
    policy = as_policy(cfg)
    B = batch_size_of(batch)
    res = resolve_policy(policy, flatten(params))
    gfn = jax.grad(lambda p, s: _single(apply_fn, p, s))
    sq_rows = jax.lax.map(
        lambda s: jnp.stack(_unit_sq_norms(flatten(gfn(params, s)), res, B,
                                           leading_batch=False)), batch)
    sq = [sq_rows[:, u] for u in range(len(res.units))]
    unit_norms, unit_C = unit_clip_factors(res, sq)

    losses, flat = _unit_weighted_grads(apply_fn, params, batch, res, unit_C)
    flat = finalize_noise(policy, res, flat, rng, float(B), step,
                          mesh=mesh, pspecs=pspecs)
    return unflatten(flat), norm_aux(res, losses, sq, unit_norms, unit_C)


def ghostclip_grad(apply_fn, params, batch, rng, cfg, step=None,
                   mesh=None, pspecs=None):
    """Li et al. 2021 / Bu et al. 2022a: ghost norms from a tapped first
    backprop (no per-sample grads), then a second full backprop per unit."""
    policy = as_policy(cfg)
    B = batch_size_of(batch)
    flat_params = flatten(params)
    tap_struct = tap_structs(apply_fn, params, batch)
    _, psp_paths = split_param_paths(params, tap_struct)
    res = resolve_policy(policy, flat_params)
    active_taps = sorted(k for k in tap_struct
                         if parse_key(k)[0] + "/w" not in res.frozen)
    psp_active = [p for p in psp_paths if p not in res.frozen]
    taps0 = {k: jnp.zeros(tap_struct[k].shape, tap_struct[k].dtype)
             for k in active_taps}
    psp0 = {p: jnp.broadcast_to(flat_params[p], (B,) + flat_params[p].shape)
            for p in psp_active}

    def run(taps, psp):
        merged = dict(flat_params)
        merged.update(psp)
        tape = Tape(taps)
        losses = apply_fn(unflatten(merged), batch, tape)
        return jnp.sum(losses), tape.acts

    _, vjp_fn, acts = jax.vjp(run, taps0, psp0, has_aux=True)
    ds_taps, g_psp = vjp_fn(jnp.asarray(1.0, F32))

    sq = [jnp.zeros((B,), F32) for _ in res.units]
    for key in active_taps:
        wpath = parse_key(key)[0] + "/w"
        nk, _ = record_sq_norm(key, acts[key], ds_taps[key], "bk",
                               policy.use_kernels, res.method_for(wpath))
        u = res.unit_of[wpath]
        sq[u] = sq[u] + nk
    for p in psp_active:
        g = g_psp[p].astype(F32)
        u = res.unit_of[p]
        sq[u] = sq[u] + jnp.sum(g * g, axis=tuple(range(1, g.ndim)))
    unit_norms, unit_C = unit_clip_factors(res, sq)

    losses, flat = _unit_weighted_grads(apply_fn, params, batch, res, unit_C)
    flat = finalize_noise(policy, res, flat, rng, float(B), step,
                          mesh=mesh, pspecs=pspecs)
    return unflatten(flat), norm_aux(res, losses, sq, unit_norms, unit_C)
