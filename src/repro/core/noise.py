"""DP noise mechanisms (pluggable via PrivacyPolicy.noise).

``add_noise`` draws per-leaf Gaussian noise with a path-stable RNG split so
the noise is reproducible per parameter regardless of tree iteration order.

A ``NoiseMechanism`` is any object with

    add(flat_grads, rng, sigma, sensitivity, denom, step=None) -> dict

returning ``(G + sigma * sensitivity * xi) / denom`` per leaf, where
``sensitivity`` is the policy's composed L2 sensitivity (a bare R for flat
clipping). Two are registered:

  'gaussian'  the classic Gaussian mechanism (per-step independent noise)
  'tree'      binary-tree aggregation (Kairouz et al. 2021, DP-FTRL): the
              CUMULATIVE noise over steps 1..t is the sum of the O(log t)
              tree-node noises covering [1..t]; ``add`` injects the per-step
              increment N(t) - N(t-1) so the optimizer's running gradient
              sum carries exactly N(t). Node noise is keyed by a fixed seed
              (NOT the per-step rng) so node draws are shared across steps
              and the increments telescope.

``partial_sigma`` implements the distributed-noise trick: on an n-way data
axis each shard adds N(0, (sigma/sqrt(n))^2) *before* the gradient
all-reduce; the reduced sum then carries exactly N(0, sigma^2) — identical
privacy, no single-host noise-generation bottleneck. (Used by the launcher
when ``dp.distributed_noise`` is on.)
"""
from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp


def _path_rng(rng, path: str):
    return jax.random.fold_in(rng, zlib.crc32(path.encode()) & 0x7FFFFFFF)


def add_noise(flat_grads: dict, rng, sigma: float, R: float, denom: float) -> dict:
    """(G + sigma*R*xi) / denom per leaf. sigma==0 -> just G/denom."""
    out = {}
    for path, g in flat_grads.items():
        if sigma > 0.0:
            xi = jax.random.normal(_path_rng(rng, path), g.shape, jnp.float32)
            g = g + (sigma * R) * xi.astype(g.dtype)
        out[path] = g / denom
    return out


def partial_sigma(sigma: float, n_shards: int) -> float:
    return sigma / (n_shards ** 0.5)


# ----------------------------------------------------------------- mechanisms
class GaussianMechanism:
    """Per-step independent Gaussian noise — the DP-SGD default."""
    name = "gaussian"

    def __init__(self, seed: int = 0, depth: int = 0):
        del seed, depth  # stateless: noise comes from the per-step rng

    def add(self, flat_grads: dict, rng, sigma: float, sensitivity: float,
            denom: float, step=None) -> dict:
        del step  # per-step independence: the per-call rng is the state
        return add_noise(flat_grads, rng, sigma, sensitivity, denom)


class TreeAggregationMechanism:
    """Binary-tree aggregated noise (DP-FTRL).

    Node (level l, index i>=1) covers steps [(i-1)*2^l + 1, i*2^l]. At step t
    (1-indexed) the prefix [1..t] is covered by one node per set bit b of t,
    with index i = t >> b — so the cumulative noise N(t) sums popcount(t)
    unit-variance node draws, giving per-coordinate variance
    popcount(t) * (sigma * sensitivity)^2 <= (log2(t)+1) * (sigma * S)^2
    instead of the t * (sigma * S)^2 of per-step independent noise on a
    released prefix sum.

    The per-call ``rng`` is IGNORED: node noises must be identical whenever
    the same node covers different prefixes, so they key off the fixed
    ``seed`` + (path, level, index) only. ``step`` may be a python int or a
    traced jnp scalar (the node indices are data to ``fold_in``).

    Cost note: with a traced step every level draws a full leaf-sized normal
    (the dead levels' zero weights can't be DCE'd), i.e. 2*depth draws per
    leaf per ``add``. ``depth`` only needs to cover the horizon
    (2^depth - 1 steps) — set ``PrivacyPolicy.noise_depth`` to
    ceil(log2(steps + 1)) to pay only what the run needs.
    """
    name = "tree"

    def __init__(self, seed: int = 0, depth: int = 30):
        self.seed = seed
        self.depth = depth           # supports up to 2^depth - 1 steps

    def _node(self, path: str, level: int, idx):
        k = _path_rng(jax.random.PRNGKey(self.seed), path)
        return jax.random.fold_in(jax.random.fold_in(k, level), idx)

    def prefix_noise(self, path: str, shape, t, dtype=jnp.float32):
        """N(t): unit-variance-per-node cumulative noise for steps [1..t]."""
        out = jnp.zeros(shape, dtype)
        for b in range(self.depth):
            i = t >> b
            z = jax.random.normal(self._node(path, b, i), shape, dtype)
            out = out + jnp.asarray(i & 1, dtype) * z
        return out

    def add(self, flat_grads: dict, rng, sigma: float, sensitivity: float,
            denom: float, step=None) -> dict:
        del rng
        if sigma > 0.0 and step is None:
            # a forgotten step would re-add the IDENTICAL N(1)-N(0) draw
            # every call — differences of released grads become noise-free.
            # Fail loudly instead of silently voiding the guarantee.
            raise ValueError(
                "tree aggregation is stateful: pass the step index — "
                "grad_fn(params, batch, rng, step) / engine.grad(..., step)")
        t = (step if step is not None else 0) + 1  # steps are 0-indexed
        out = {}
        for path, g in flat_grads.items():
            if sigma > 0.0:
                delta = (self.prefix_noise(path, g.shape, t)
                         - self.prefix_noise(path, g.shape, t - 1))
                g = g + (sigma * sensitivity) * delta.astype(g.dtype)
            out[path] = g / denom
        return out


NOISE_MECHANISMS = {
    "gaussian": GaussianMechanism,
    "tree": TreeAggregationMechanism,
}


def get_mechanism(name: str, seed: int = 0, depth: int = 0):
    try:
        cls = NOISE_MECHANISMS[name]
    except KeyError:
        raise ValueError(f"unknown noise mechanism {name!r}; options: "
                         f"{sorted(NOISE_MECHANISMS)}")
    return cls(seed=seed, depth=depth) if depth else cls(seed=seed)
