"""DP Gaussian noise addition.

``add_noise`` draws per-leaf Gaussian noise with a path-stable RNG split so
the noise is reproducible per parameter regardless of tree iteration order.

``partial_sigma`` implements the distributed-noise trick: on an n-way data
axis each shard adds N(0, (sigma/sqrt(n))^2) *before* the gradient
all-reduce; the reduced sum then carries exactly N(0, sigma^2) — identical
privacy, no single-host noise-generation bottleneck. (Used by the launcher
when ``dp.distributed_noise`` is on.)
"""
from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp


def _path_rng(rng, path: str):
    return jax.random.fold_in(rng, zlib.crc32(path.encode()) & 0x7FFFFFFF)


def add_noise(flat_grads: dict, rng, sigma: float, R: float, denom: float) -> dict:
    """(G + sigma*R*xi) / denom per leaf. sigma==0 -> just G/denom."""
    out = {}
    for path, g in flat_grads.items():
        if sigma > 0.0:
            xi = jax.random.normal(_path_rng(rng, path), g.shape, jnp.float32)
            g = g + (sigma * R) * xi.astype(g.dtype)
        out[path] = g / denom
    return out


def partial_sigma(sigma: float, n_shards: int) -> float:
    return sigma / (n_shards ** 0.5)
