"""DP noise mechanisms (pluggable via PrivacyPolicy.noise).

``add_noise`` draws per-leaf Gaussian noise with a path-stable RNG split so
the noise is reproducible per parameter regardless of tree iteration order.

A ``NoiseMechanism`` is any object with

    add(flat_grads, rng, sigma, sensitivity, denom, step=None) -> dict

plus the restart hooks

    state_dict() -> dict      # everything a privacy-exact restart needs
    load_state(state) -> None # restore/validate; raise ValueError on drift

State dicts are persisted inside every checkpoint (``checkpoint.run_state``)
and replayed at resume BEFORE the first restored step runs. Both mechanisms
here are counter-based — their noise at step t is a pure function of
(seed, path, t) — so their restorable state is exactly their configuration,
and ``load_state`` is a drift guard: resuming with a different node seed,
restart period or completion flag would silently put the run on a fresh
noise path (re-drawing noise the adversary has already seen answered
differently — a privacy violation, not just a reproducibility bug), so it
raises instead. A future *stateful* mechanism (e.g. banded matrix
factorization holding an O(band) buffer) returns its buffers as numpy
arrays inside ``state_dict``; the RunState packer stores array-valued
entries in the sliced checkpoint payload and round-trips them bitwise.

returning ``(G + sigma * scale * xi) / denom`` per leaf, where ``scale`` is
either one L2 sensitivity shared by every leaf (a bare R for flat clipping,
the policy's composed sensitivity for group-wise clipping) or a
``{path: scale}`` mapping for heterogeneous per-group noise
(``ParamGroup.sigma_scale``; the accounting composes the per-group Gaussian
curves jointly — see ``accounting.compute_epsilon``). Two are registered:

  'gaussian'  the classic Gaussian mechanism (per-step independent noise)
  'tree'      binary-tree aggregation (Kairouz et al. 2021, DP-FTRL): the
              CUMULATIVE noise over steps 1..t is the sum of the O(log t)
              tree-node noises covering [1..t]; ``add`` injects the per-step
              increment N(t) - N(t-1) so the optimizer's running gradient
              sum carries exactly N(t). Node noise is keyed by a fixed seed
              (NOT the per-step rng) so node draws are shared across steps
              and the increments telescope.

Tree restarts (DP-FTRL epoch restarts): with ``restart_every=E`` the tree is
rebuilt every E steps — epoch e = step // E gets its own node seeds and the
local prefix index resets to 1, matching an FTRL optimizer that rebases
theta0 and zeroes its gradient prefix at the same boundary (``optim.ftrl``).
With ``completion=True`` (the honest-restart variance correction, Honaker
completion as in the DP-FTRL reference code) the LAST increment of each
epoch advances the prefix to the next power of two, so the noise baked into
the restart point is the completed tree's root path — popcount(2^k) = 1 node
of variance instead of popcount(E) — at no extra privacy cost (every tree
node is already released).

``partial_sigma`` implements the distributed-noise trick: on an n-way data
axis each shard adds N(0, (sigma/sqrt(n))^2) *before* the gradient
all-reduce; the reduced sum then carries exactly N(0, sigma^2) — identical
privacy, no single-host noise-generation bottleneck. (Used by the launcher
when ``dp.distributed_noise`` is on.)

Shard-local generation: ``sharded_normal`` draws each param's noise under a
mesh so every device generates ONLY its NamedSharding slice — no replicated
full-parameter noise tensor ever exists in HBM (the dominant phase-4
allocation for large models). Generation is COUNTER-BASED
(``counter_normal``): the value at a tensor's global coordinate is a pure
function of (key, global linear index) via threefry-2x32 + the inverse
normal CDF, so the same (seed, shape) produces BITWISE-identical noise on
1 device, 8 devices, or any mesh shape — sigma>0 runs are mesh-portable,
not just statistically matched (previously draws were keyed per
(shard index, mesh) and only sigma=0 runs were portable).
"""
from __future__ import annotations

import zlib
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def _path_rng(rng, path: str):
    return jax.random.fold_in(rng, zlib.crc32(path.encode()) & 0x7FFFFFFF)


def _spec_axis_names(entry):
    """PartitionSpec entry -> tuple of mesh axis names (may be nested)."""
    if entry is None:
        return ()
    if isinstance(entry, (tuple, list)):
        return tuple(entry)
    return (entry,)


def _raw_key(rng):
    """PRNGKey -> raw uint32[2] key data (typed new-style keys included)."""
    if jnp.issubdtype(rng.dtype, jax.dtypes.prng_key):
        return jax.random.key_data(rng)
    return rng


def counter_normal(rng, shape, dtype=jnp.float32, offsets=None,
                   full_shape=None):
    """Counter-based N(0,1): the value at global coordinate x is a pure
    function of (key, linear index of x within ``full_shape``) — one
    threefry-2x32 block per element with the index as the counter (the raw
    block primitive: the high-level hashes pair positions across the array,
    making values length-dependent), 24 mantissa bits to a (0,1) uniform,
    then the inverse normal CDF. A device holding only the local block
    passes its per-dim global ``offsets``; any partition of the same
    (key, full_shape) reproduces bitwise the same global tensor.

    Tensors past 2^32 elements split the counter across BOTH threefry
    words: the trailing dims that fit a uint32 ride word 0 (so tensors
    under 2^32 keep their exact pre-split draws), the leading-block index
    rides word 1."""
    from jax.extend.random import threefry2x32_p
    from jax.scipy.special import ndtri
    full = tuple(full_shape) if full_shape is not None else tuple(shape)
    # split point: dims [k:] index counter word 0 exactly; dims [:k] word 1
    k, trail = len(full), 1
    while k > 0 and trail * int(full[k - 1]) < (1 << 32):
        k -= 1
        trail *= int(full[k])
    lead = 1
    for s in full[:k]:
        lead *= int(s)
    if lead >= 1 << 32:
        raise ValueError(
            f"counter_normal supports < 2^64 elements per tensor (and no "
            f"single dim >= 2^32), got shape {full}")

    def plane(dims) -> jnp.ndarray:
        idx = jnp.zeros(shape, jnp.uint32)
        stride = 1
        for d in reversed(dims):
            coord = jax.lax.broadcasted_iota(jnp.uint32, shape, d)
            if offsets is not None:
                coord = coord + jnp.uint32(offsets[d])
            idx = idx + coord * jnp.uint32(stride)
            stride *= int(full[d])
        return idx.reshape(-1)

    key = _raw_key(rng)
    lo, hi = plane(range(k, len(full))), plane(range(k))
    bits, _ = threefry2x32_p.bind(jnp.broadcast_to(key[0], lo.shape),
                                  jnp.broadcast_to(key[1], lo.shape),
                                  lo, hi)
    bits = bits.reshape(shape)
    u = (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2 ** -24) \
        + jnp.float32(2 ** -25)
    return ndtri(u).astype(dtype)


def sharded_normal(rng, shape, dtype=jnp.float32, mesh=None, spec=None):
    """N(0,1) draw where each device generates only its shard, bitwise
    IDENTICAL across device counts and mesh shapes.

    ``spec`` is the leaf's PartitionSpec on ``mesh``. The draw runs inside a
    shard_map: every shard computes its global per-dim offsets from its axis
    indices and generates its local block with :func:`counter_normal`, so
    the per-device noise buffer is slice-sized while the assembled logical
    tensor equals the unsharded draw exactly (ROADMAP PR-4 follow-up: noise
    is now indexed by global coordinates, not by (shard, mesh)). Mesh axes
    the spec does not mention produce identical blocks, so the output is
    genuinely replicated across them. Falls back to the unsharded
    counter-based draw (same values, GSPMD-partitioned) when there is no
    mesh, the spec is trivial, or a sharded dim does not divide."""
    if mesh is None or spec is None:
        return counter_normal(rng, shape, dtype)
    tail = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    names = [n for e in tail for n in _spec_axis_names(e)]
    if not names or all(mesh.shape[n] == 1 for n in names):
        return counter_normal(rng, shape, dtype)
    local_shape = []
    for dim, entry in zip(shape, tail):
        n = 1
        for a in _spec_axis_names(entry):
            n *= mesh.shape[a]
        if dim % n:
            return counter_normal(rng, shape, dtype)  # non-divisible
        local_shape.append(dim // n)
    local_shape = tuple(local_shape)

    def draw(key):
        offs = []
        for dim, loc, entry in zip(shape, local_shape, tail):
            idx = jnp.uint32(0)
            for a in _spec_axis_names(entry):
                idx = idx * jnp.uint32(mesh.shape[a]) \
                    + jnp.uint32(jax.lax.axis_index(a))
            offs.append(idx * jnp.uint32(loc))
        return counter_normal(key, local_shape, dtype, offsets=offs,
                              full_shape=shape)

    from jax.experimental.shard_map import shard_map
    return shard_map(draw, mesh=mesh, in_specs=P(),
                     out_specs=P(*tail), check_rep=False)(rng)


def _scale_for(sensitivity, path: str) -> float:
    """Per-leaf noise scale: a shared float or a {path: scale} mapping."""
    if isinstance(sensitivity, Mapping):
        return sensitivity[path]
    return sensitivity


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (tree-completion horizon)."""
    return 1 << max(0, int(n) - 1).bit_length()


def _spec_of(pspecs, path: str):
    """Per-leaf PartitionSpec lookup (None mesh/pspecs -> replicated draw)."""
    if pspecs is None:
        return None
    return pspecs.get(path)


def add_noise(flat_grads: dict, rng, sigma: float, R, denom: float,
              mesh=None, pspecs=None) -> dict:
    """(G + sigma*R*xi) / denom per leaf. sigma==0 -> just G/denom.
    ``R`` may be a float (shared scale) or a {path: scale} mapping; with
    ``mesh``/``pspecs`` each device draws only its slice of xi."""
    out = {}
    for path, g in flat_grads.items():
        if sigma > 0.0:
            xi = sharded_normal(_path_rng(rng, path), g.shape, jnp.float32,
                                mesh=mesh, spec=_spec_of(pspecs, path))
            g = g + (sigma * _scale_for(R, path)) * xi.astype(g.dtype)
        out[path] = g / denom
    return out


def partial_sigma(sigma: float, n_shards: int) -> float:
    return sigma / (n_shards ** 0.5)


# ----------------------------------------------------------------- mechanisms
class GaussianMechanism:
    """Per-step independent Gaussian noise — the DP-SGD default."""
    name = "gaussian"

    def __init__(self, seed: int = 0, depth: int = 0,
                 restart_every: int = 0, completion: bool = False):
        del seed, depth, restart_every, completion  # stateless: per-step rng

    def state_dict(self) -> dict:
        """Per-step noise is keyed off the step rng the TrainState already
        persists — the mechanism itself carries no restorable state."""
        return {"name": self.name}

    def load_state(self, state: dict) -> None:
        if state.get("name") != self.name:
            raise ValueError(
                f"checkpoint noise state is {state.get('name')!r} but the "
                f"resumed run configures {self.name!r} — resuming would "
                "switch the noise mechanism mid-release")

    def add_leaf(self, path: str, g, rng, sigma: float, scale,
                 denom: float, step=None, mesh=None, spec=None):
        """One leaf of ``add`` — the fused noise+optimizer path consumes
        leaves one at a time so the full noised-gradient tree is never
        live."""
        del step  # per-step independence: the per-call rng is the state
        if sigma > 0.0:
            xi = sharded_normal(_path_rng(rng, path), g.shape, jnp.float32,
                                mesh=mesh, spec=spec)
            g = g + (sigma * scale) * xi.astype(g.dtype)
        return g / denom

    def add(self, flat_grads: dict, rng, sigma: float, sensitivity,
            denom: float, step=None, mesh=None, pspecs=None) -> dict:
        return {path: self.add_leaf(path, g, rng, sigma,
                                    _scale_for(sensitivity, path), denom,
                                    step=step, mesh=mesh,
                                    spec=_spec_of(pspecs, path))
                for path, g in flat_grads.items()}


class TreeAggregationMechanism:
    """Binary-tree aggregated noise (DP-FTRL).

    Node (level l, index i>=1) covers steps [(i-1)*2^l + 1, i*2^l]. At step t
    (1-indexed) the prefix [1..t] is covered by one node per set bit b of t,
    with index i = t >> b — so the cumulative noise N(t) sums popcount(t)
    unit-variance node draws, giving per-coordinate variance
    popcount(t) * (sigma * sensitivity)^2 <= (log2(t)+1) * (sigma * S)^2
    instead of the t * (sigma * S)^2 of per-step independent noise on a
    released prefix sum.

    The per-call ``rng`` is IGNORED: node noises must be identical whenever
    the same node covers different prefixes, so they key off the fixed
    ``seed`` + (path, epoch, level, index) only. ``step`` may be a python int
    or a traced jnp scalar (node/epoch indices are data to ``fold_in``).

    ``restart_every=E`` rebuilds the tree every E steps (epoch restarts):
    step t maps to epoch e = step//E with local prefix index (step % E) + 1,
    and every epoch draws from fresh node seeds. An FTRL optimizer zeroes its
    gradient prefix at the same boundary, so the first increment of a new
    epoch is the full N_e(1) of the fresh tree. ``completion=True``
    additionally advances the LAST increment of each epoch to
    N_e(next_pow2(E)), so the model state that the restart rebases on
    carries single-root-node noise variance (the honest-restart correction);
    it is a no-op when E is a power of two.

    Cost note: with a traced step every level draws a full leaf-sized normal
    (the dead levels' zero weights can't be DCE'd), i.e. 2*depth draws per
    leaf per ``add``. ``depth`` only needs to cover the horizon
    (2^depth - 1 steps; next_pow2(E) under restarts) — set
    ``PrivacyPolicy.noise_depth`` to ceil(log2(steps + 1)) to pay only what
    the run needs.
    """
    name = "tree"

    def __init__(self, seed: int = 0, depth: int = 30,
                 restart_every: int = 0, completion: bool = False):
        self.seed = seed
        self.depth = depth           # supports up to 2^depth - 1 steps
        self.restart_every = int(restart_every)
        self.completion = bool(completion)
        if self.completion and self.restart_every <= 0:
            raise ValueError("tree completion needs restart_every > 0 "
                             "(it corrects the noise at epoch boundaries)")
        if self.restart_every > 0 and next_pow2(self.restart_every) >= (1 << depth):
            raise ValueError(
                f"depth {depth} cannot cover the per-epoch horizon "
                f"{next_pow2(self.restart_every)} (restart_every="
                f"{self.restart_every})")

    def state_dict(self) -> dict:
        """The tree's node noise is a pure function of (seed, path, epoch,
        level, index), so the restorable state is the configuration that
        keys it. Depth is deliberately EXCLUDED: node draws are
        depth-invariant (levels above the prefix contribute i&1 == 0), so
        depth is a draw-cost knob, not part of the noise path."""
        return {"name": self.name, "seed": self.seed,
                "restart_every": self.restart_every,
                "completion": self.completion}

    def load_state(self, state: dict) -> None:
        """Validate that this mechanism continues the checkpointed release.
        A mismatched seed re-draws every released node; a mismatched
        restart period or completion flag shifts every epoch boundary —
        either silently voids the restart-exactness guarantee, so both
        raise."""
        mine = self.state_dict()
        drift = {k: (state.get(k), mine[k]) for k in mine
                 if state.get(k) != mine[k]}
        if drift:
            raise ValueError(
                "tree-noise state drift between checkpoint and resumed run "
                "(checkpointed != configured): "
                + ", ".join(f"{k}: {a!r} != {b!r}"
                            for k, (a, b) in sorted(drift.items())))

    def _node(self, path: str, level: int, idx, epoch=0):
        k = _path_rng(jax.random.PRNGKey(self.seed), path)
        k = jax.random.fold_in(k, epoch)
        return jax.random.fold_in(jax.random.fold_in(k, level), idx)

    def prefix_noise(self, path: str, shape, t, dtype=jnp.float32, epoch=0,
                     mesh=None, spec=None):
        """N_e(t): unit-variance-per-node cumulative noise for the epoch's
        steps [1..t]. With ``mesh``/``spec`` every node draw is shard-local
        (each device holds slice-sized node noise only)."""
        out = jnp.zeros(shape, dtype)
        for b in range(self.depth):
            i = t >> b
            z = sharded_normal(self._node(path, b, i, epoch), shape, dtype,
                               mesh=mesh, spec=spec)
            out = out + jnp.asarray(i & 1, dtype) * z
        return out

    def _epoch_local(self, step):
        """Global 0-indexed step -> (epoch, local 1-indexed prefix t)."""
        if self.restart_every <= 0:
            return 0, step + 1
        return step // self.restart_every, (step % self.restart_every) + 1

    def _local_prefix(self, sigma: float, step):
        """Validated (epoch, t, t_hi) for one call (shared by every leaf)."""
        if sigma > 0.0 and step is None:
            # a forgotten step would re-add the IDENTICAL N(1)-N(0) draw
            # every call — differences of released grads become noise-free.
            # Fail loudly instead of silently voiding the guarantee.
            raise ValueError(
                "tree aggregation is stateful: pass the step index — "
                "grad_fn(params, batch, rng, step) / engine.grad(..., step)")
        epoch, t = self._epoch_local(step if step is not None else 0)
        if isinstance(t, (int, np.integer)) and t >= (1 << self.depth):
            # past the horizon every level index t>>b goes even and N(t)
            # collapses toward zero — increments would SUBTRACT released
            # noise, silently voiding the guarantee. (Traced steps can't be
            # checked here; size depth from the run length as the train
            # driver does.)
            raise ValueError(
                f"step {t - 1} exceeds the tree horizon 2^depth-1 = "
                f"{(1 << self.depth) - 1}; raise depth (or set "
                "restart_every) to cover the run")
        t_hi = t
        if self.completion:
            # last step of the epoch: advance the prefix to the completed
            # tree so the FTRL restart rebases on single-root-node noise
            t_hi = jnp.where(t == self.restart_every,
                             next_pow2(self.restart_every), t)
        return epoch, t, t_hi

    def add_leaf(self, path: str, g, rng, sigma: float, scale,
                 denom: float, step=None, mesh=None, spec=None):
        del rng  # node noise keys off the fixed seed only
        epoch, t, t_hi = self._local_prefix(sigma, step)
        if sigma > 0.0:
            delta = (self.prefix_noise(path, g.shape, t_hi, epoch=epoch,
                                       mesh=mesh, spec=spec)
                     - self.prefix_noise(path, g.shape, t - 1, epoch=epoch,
                                         mesh=mesh, spec=spec))
            g = g + (sigma * scale) * delta.astype(g.dtype)
        return g / denom

    def add(self, flat_grads: dict, rng, sigma: float, sensitivity,
            denom: float, step=None, mesh=None, pspecs=None) -> dict:
        return {path: self.add_leaf(path, g, rng, sigma,
                                    _scale_for(sensitivity, path), denom,
                                    step=step, mesh=mesh,
                                    spec=_spec_of(pspecs, path))
                for path, g in flat_grads.items()}


NOISE_MECHANISMS = {
    "gaussian": GaussianMechanism,
    "tree": TreeAggregationMechanism,
}


def get_mechanism(name: str, seed: int = 0, depth: int | None = None,
                  restart_every: int = 0, completion: bool = False):
    """Build a registered mechanism. ``depth`` None/0 means "the mechanism's
    own default" (TreeAggregationMechanism keeps its 30) — the argument is a
    pass-through, never a clobber."""
    try:
        cls = NOISE_MECHANISMS[name]
    except KeyError:
        raise ValueError(f"unknown noise mechanism {name!r}; options: "
                         f"{sorted(NOISE_MECHANISMS)}")
    kw = {"seed": seed, "restart_every": restart_every,
          "completion": completion}
    if depth:  # 0/None -> keep the class default (regression: a depth-0 tree)
        kw["depth"] = depth
    return cls(**kw)
