"""Step builders: (arch x shape x mesh) -> jit-able function + abstract args
+ shardings. Used by the dry-run (lower/compile on ShapeDtypeStructs) and by
the real train/serve drivers.

``TrainState`` + ``make_train_step`` are the single source of truth for the
production train step: a mesh-lowered, donation-clean jitted function over
(state, batch) with explicit in/out shardings. The dryrun planner, the real
``launch.train`` driver, ``benchmarks.step_bench`` and the sharded tests all
build the same step through here."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.configs.registry import build, get_config, get_policy, has_policy
from repro.core.bk import BK_MODES, DPConfig
from repro.core.policy import as_policy, noise_leaf_fn, resolve_policy
from repro.data.synthetic import batch_spec
from repro.launch import sharding as sh
from repro.optim.accumulate import (accumulated_clipped_sum,
                                    accumulated_private_grad)
from repro.optim.optimizers import make_optimizer
from repro.utils.tree import flatten


@dataclass
class TrainState:
    """The donated unit of the train loop: everything a step consumes and
    produces. ``step`` is a () int32 on device; ``rng`` is the BASE key —
    each step folds its own index in, so the state never needs a host-side
    rng update and resume is bit-exact from (seed, step) alone."""
    params: dict
    opt_state: dict
    step: jax.Array
    rng: jax.Array


jax.tree_util.register_dataclass(
    TrainState, data_fields=("params", "opt_state", "step", "rng"),
    meta_fields=())


def init_train_state(params, opt_state, step: int, rng,
                     state_sh=None) -> TrainState:
    """Assemble the donated TrainState (fresh init or checkpoint resume),
    placing params/opt_state against the step's shardings when given.

    ``rng`` is the BASE key (raw uint32[2]); a resumed run passes the
    CHECKPOINTED key here verbatim — the step function folds the absolute
    step into it, so handing back the same base key replays the exact
    per-step key sequence the interrupted run would have used."""
    state = TrainState(params=params, opt_state=opt_state,
                       step=jnp.asarray(step, jnp.int32),
                       rng=jnp.asarray(rng, jnp.uint32))
    if state_sh is not None:
        state = TrainState(
            params=jax.device_put(state.params, state_sh.params),
            opt_state=jax.device_put(state.opt_state, state_sh.opt_state),
            step=state.step, rng=state.rng)
    return state


def make_train_step(apply_fn, params_like, opt, opt_name: str, dp,
                    microbatch: int, mesh, batch_like):
    """-> (step_fn, state_shardings, batch_shardings).

    ``step_fn(state, batch) -> (new_state, loss)`` is pure and built for

        jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None), donate_argnums=(0,))

    Inside: BK runs mesh-lowered (batch-sharded book-keeping, one psum per
    weighted grad), phase-4 noise is generated shard-local, and — whenever
    the optimizer has a fused per-leaf path — the noise-add and the
    optimizer update happen in ONE pass over the leaves, so no second
    full-parameter-size gradient tree is ever live."""
    policy = as_policy(dp)
    state_sh = sh.named(mesh, sh.state_pspecs(opt_name, params_like, mesh))
    batch_sh = sh.named(mesh, sh.batch_pspecs(batch_like, mesh))
    flat_pspecs = sh.flat_param_pspecs(params_like, mesh)
    res = resolve_policy(policy, flatten(params_like))

    def step_fn(state, batch):
        rng = jax.random.fold_in(state.rng, state.step)
        if policy.mode in BK_MODES and opt.update_leaves is not None:
            sums, aux, B = accumulated_clipped_sum(
                apply_fn, state.params, batch, policy, microbatch, mesh=mesh,
                rng=rng)
            leaf = noise_leaf_fn(policy, res, rng, float(B), step=state.step,
                                 mesh=mesh, pspecs=flat_pspecs)
            new_p, new_o = opt.update_leaves(
                lambda path, p: leaf(path, sums[path]),
                state.opt_state, state.params, state.step)
        else:
            grads, aux = accumulated_private_grad(
                apply_fn, state.params, batch, rng, policy, microbatch,
                state.step, mesh=mesh, pspecs=flat_pspecs)
            new_p, new_o = opt.update(grads, state.opt_state, state.params,
                                      state.step)
        new_state = TrainState(params=new_p, opt_state=new_o,
                               step=state.step + 1, rng=state.rng)
        return new_state, aux["loss"]

    return step_fn, state_sh, batch_sh

# physical (micro) batch for train_4k, tuned so the per-device book-keeping
# footprint stays within v5e HBM (see EXPERIMENTS.md §Dry-run)
TRAIN_MICROBATCH = {
    # >= data-axis size (16) so the microbatch stays shardable over 'data'
    "llama3-405b": 16, "internvl2-26b": 16, "qwen3-14b": 16,
    "deepseek-moe-16b": 16, "moonshot-v1-16b-a3b": 16,
    "qwen2-1.5b": 32, "qwen2.5-3b": 32, "whisper-small": 32,
    "rwkv6-3b": 16, "hymba-1.5b": 16,
}
TRAIN_OPTIMIZER = {"llama3-405b": "adafactor"}
SUBQUADRATIC = ("ssm", "hybrid")


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC:
        return ("full-attention arch: 524k dense-KV decode is quadratic-cost/"
                "unbounded-KV by construction; run only for SSM/hybrid "
                "(DESIGN.md §4)")
    return None


@dataclass
class CellPlan:
    arch: str
    shape: str
    kind: str
    fn: Callable
    args: tuple                  # ShapeDtypeStructs
    in_shardings: tuple
    donate: tuple = ()
    note: str = ""

    def jitted(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       donate_argnums=self.donate)

    def lower(self):
        mesh = None
        for sh in jax.tree_util.tree_leaves(self.in_shardings):
            if hasattr(sh, "mesh"):
                mesh = sh.mesh
                break
        if mesh is not None:
            with mesh:  # Mesh is the context manager (jax.set_mesh is newer)
                return self.jitted().lower(*self.args)
        return self.jitted().lower(*self.args)


def _key_struct():
    return jax.ShapeDtypeStruct((2,), jnp.uint32)


def _params_struct(model):
    return jax.eval_shape(model.init, _key_struct())


def plan_cell(arch: str, shape_name: str, mesh, dp=None,
              microbatch: Optional[int] = None, cfg_patch: Optional[dict] = None,
              optimizer: Optional[str] = None,
              clipping_scope: str = "") -> CellPlan:
    """``dp`` is a DPConfig, a PrivacyPolicy, or None — None picks the
    arch's registered policy preset when one exists (group-wise planning),
    else the flat bk-mixopt DPConfig. ``clipping_scope`` re-scopes every
    trainable group (policy.with_scope) before planning — 'layer' plans the
    streamed one-pass backward (train cells only)."""
    cfg = get_config(arch)
    if cfg_patch:
        cfg = cfg.with_(**cfg_patch)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        raise LookupError(reason)
    model = build(cfg)
    params = _params_struct(model)
    pspec = sh.param_pspecs(params, mesh)
    psh = sh.named(mesh, pspec)

    if shape.kind == "train":
        # bk-mixopt IS the paper's algorithm at T=4096 (§3: large-T needs the
        # layerwise hybrid; base-BK's 2BT^2 Grams are the wrong branch here).
        # When the arch registers a PrivacyPolicy preset the dryrun grid
        # plans THAT (group-wise norm accumulators + per-unit clip factors
        # change the book-keeping HBM), not a flat DPConfig.
        policy_tag = ""
        if dp is None and has_policy(arch):
            dp = get_policy(arch, mode="bk-mixopt", sigma=1.0)
            policy_tag = f" policy={arch}({len(dp.groups)}g)"
        dp = dp or DPConfig(mode="bk-mixopt", clipping="automatic", sigma=1.0)
        if clipping_scope:
            from repro.core.policy import with_scope
            dp = with_scope(dp, clipping_scope)
            policy_tag += f" scope={clipping_scope}"
        mb = microbatch or TRAIN_MICROBATCH.get(arch, 16)
        opt_name = optimizer or TRAIN_OPTIMIZER.get(arch, "adamw")
        opt = make_optimizer(opt_name, lambda s: jnp.asarray(1e-4, jnp.float32))
        bspec = batch_spec(cfg, shape.global_batch, shape.seq_len,
                           dtype=cfg.dtype)
        ostate = jax.eval_shape(opt.init, params)
        step_fn, state_sh, bsh = make_train_step(
            model.apply, params, opt, opt_name, dp, mb, mesh, bspec)
        state = TrainState(params=params, opt_state=ostate,
                           step=jax.ShapeDtypeStruct((), jnp.int32),
                           rng=_key_struct())
        return CellPlan(
            arch, shape_name, "train", step_fn, (state, bspec),
            (state_sh, bsh), donate=(0,),
            note=f"dp={as_policy(dp).mode} micro={mb} opt={opt_name}"
                 f"{policy_tag}")

    if shape.kind == "prefill":
        bspec = batch_spec(cfg, shape.global_batch, shape.seq_len,
                           dtype=cfg.dtype)
        bsh = sh.named(mesh, sh.batch_pspecs(bspec, mesh))
        if cfg.family == "encdec":
            fn = lambda p, b: model.prefill(p, b["frames"], b["tokens"])
        elif cfg.family == "vlm":
            fn = lambda p, b: model.prefill(p, b["tokens"], b["patches"])
        else:
            fn = lambda p, b: model.prefill(p, b["tokens"])
        return CellPlan(arch, shape_name, "prefill", fn, (params, bspec),
                        (psh, bsh))

    # decode
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        cache = jax.eval_shape(lambda: model.init_cache(B, S, Tf=S))
    else:
        cache = jax.eval_shape(lambda: model.init_cache(B, S))
    csh = sh.named(mesh, sh.cache_pspecs(cache, mesh))
    toks = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def serve_step(p, c, t, i):
        return model.decode_step(p, c, t, i)

    tsh = sh.named(mesh, sh.batch_pspecs(toks, mesh))
    return CellPlan(arch, shape_name, "decode", serve_step,
                    (params, cache, toks, pos), (psh, csh, tsh, None),
                    donate=(1,))
