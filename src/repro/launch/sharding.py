"""Sharding rules: param-path regex -> PartitionSpec over the trailing dims
(leading stacked/layer dims padded with None). FSDP on 'data', TP on
'model'; the 'pod' axis is pure DP (params replicated across pods).

MaxText-style first-match-wins table; 2D fallback shards the larger matmul
dim on 'model' and the other on 'data' (FSDP+TP)."""
from __future__ import annotations

import re

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.utils.tree import flatten, unflatten

# (regex on param path, spec for the TRAILING dims)
RULES = [
    # d over 'model' (not V): embedding gathers then need no cross-shard
    # indexing (SPMD gather on a vocab-sharded table falls back to full
    # rematerialization), and odd vocab sizes (32001, 51865, 92553) need no
    # padding. The table is replicated over 'data' (<=300MB/chip at 405B).
    (r"(^|/)embed/w$", (None, "model")),            # (V, d)
    (r"(^|/)head/w$", ("data", "model")),           # (d, V)
    (r"experts/up/w$", ("model", "data", None)),    # (E, d, ff) expert-parallel
    (r"experts/down/w$", ("model", None, "data")),  # (E, ff, d)
    (r"(^|/)router/w$", ("data", None)),            # (d, E)
    (r"(^|/)qkv/w$", ("data", "model")),
    (r"(^|/)o/w$", ("model", "data")),
    (r"(^|/)fuse_o/w$", ("model", "data")),
    (r"(^|/)up/w$", ("data", "model")),
    (r"(^|/)down/w$", ("model", "data")),
    (r"(^|/)value/w$", ("model", "data")),          # rwkv ffn down-proj
    (r"(^|/)(key|receptance|r|k|v|g|xz)/w$", ("data", "model")),
    (r"(^|/)(projector|frontend)/w$", (None, "model")),
    (r"xattn/(q|kv)/w$", ("data", "model")),
    (r"xattn/o/w$", ("model", "data")),
    (r"(^|/)(wa|tm_w1|bcdt)/w$", ("data", None)),
    (r"(^|/)(wb|tm_w2_\d)/w$", (None, "model")),
    (r"(^|/)pos/e$", (None, None)),
    (r"(^|/)meta/m$", (None, None)),
]


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, (tuple, list)):
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axes]


def sanitize(spec: P, shape, mesh) -> P:
    """Drop sharding on dims the mesh axes don't divide (odd vocab sizes,
    head counts like 25/40, batch=1) — jit arguments require divisibility."""
    tail = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    out = tuple(a if a is None or shape[i] % _axis_size(mesh, a) == 0 else None
                for i, a in enumerate(tail))
    return P(*out)


def spec_for(path: str, ndim: int) -> P:
    for pat, tail in RULES:
        if re.search(pat, path):
            tail = tuple(tail)
            if len(tail) > ndim:  # e.g. scalar/vector param matched broadly
                tail = tail[-ndim:]
            return P(*((None,) * (ndim - len(tail)) + tail))
    if path.endswith("/w") and ndim >= 2:  # fallback matmul rule
        return P(*((None,) * (ndim - 2) + ("data", "model")))
    return P()  # vectors/scalars replicated


def param_pspecs(params, mesh=None) -> dict:
    out = {}
    for p, v in flatten(params).items():
        spec = spec_for(p, v.ndim)
        if mesh is not None:
            spec = sanitize(spec, v.shape, mesh)
        out[p] = spec
    return unflatten(out)


def flat_param_pspecs(params, mesh) -> dict:
    """Flat {path: sanitized PartitionSpec} — the per-leaf layout table the
    shard-local noise generator keys off (each device draws only its slice
    of each param's noise). Same rules table as param_pspecs, flattened, so
    params and their noise can never shard differently."""
    return flatten(param_pspecs(params, mesh))


def opt_state_pspecs(opt_name: str, params, param_specs) -> dict:
    """Optimizer-state specs mirror the param specs (adafactor drops the
    factored dim)."""
    pf = flatten(param_specs)
    if opt_name in ("adamw", "lamb"):
        return {"m": param_specs, "v": param_specs}
    if opt_name == "sgd":
        return {"m": param_specs}
    if opt_name == "ftrl":
        return {"sum": param_specs, "m": param_specs, "theta0": param_specs}
    if opt_name == "adafactor":
        out = {}
        for p, v in flatten(params).items():
            spec = tuple(pf[p]) + (None,) * (v.ndim - len(tuple(pf[p])))
            if v.ndim >= 2:
                out[p + "/vr"] = P(*spec[:-1])
                out[p + "/vc"] = P(*(spec[:-2] + spec[-1:]))
            else:
                out[p + "/v"] = P(*spec)
        return {"s": unflatten(out)}
    raise ValueError(opt_name)


def batch_pspecs(batch_like, mesh) -> dict:
    """Shard the leading (batch) dim of every input over pod+data."""
    from repro.launch.mesh import batch_axes
    ba = batch_axes(mesh)
    return jax.tree_util.tree_map(
        lambda x: sanitize(P(*((ba,) + (None,) * (len(x.shape) - 1))),
                           x.shape, mesh), batch_like)


def state_pspecs(opt_name: str, params, mesh):
    """PartitionSpecs for a launch.steps.TrainState: params via the rules
    table, optimizer state mirroring the params, step/rng replicated."""
    from repro.launch.steps import TrainState
    pspec = param_pspecs(params, mesh)
    return TrainState(params=pspec,
                      opt_state=opt_state_pspecs(opt_name, params, pspec),
                      step=P(), rng=P())


def cache_pspecs(cache_like, mesh) -> dict:
    """Decode caches: (L, B, S|H, ...) — batch dim over pod+data; the long
    axis (KV sequence, rwkv heads, ssm heads) over 'model'."""
    from repro.launch.mesh import batch_axes
    ba = batch_axes(mesh)

    def one(x):
        nd = len(x.shape)
        if nd >= 4:  # (L,B,S,K,h) kv cache or (L,B,H,h,h) state
            spec = P(*((None, ba, "model") + (None,) * (nd - 3)))
        elif nd == 3:  # (L,B,d) shift states
            spec = P(None, ba, "model")
        else:
            spec = P()
        return sanitize(spec, x.shape, mesh)

    return jax.tree_util.tree_map(one, cache_like)


def named(mesh, pspecs):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P))
