"""Production mesh construction.

Single pod: (data=16, model=16) — 256 chips of TPU v5e.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the pod axis is pure
data parallelism over DCN (gradient all-reduce optionally 8-bit compressed,
see repro.runtime.compression), FSDP+TP live on the ICI axes.

Defined as functions (never module-level) so importing this module does not
touch jax device state — the dry-run sets XLA_FLAGS before first jax use.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Degenerate mesh for CPU tests."""
    return jax.make_mesh(shape, axes)


def make_train_mesh(data: int = 0, model: int = 1):
    """Mesh for the real train driver, sized to whatever devices exist:
    (data=N/model, model) — one CPU gives the degenerate (1, 1) mesh, so
    every train() call runs the same mesh-lowered jit path regardless of
    topology. ``data=0`` means "all remaining devices"."""
    n = len(jax.devices())
    if model <= 0:
        model = 1
    if data <= 0:
        if n % model:
            raise ValueError(
                f"model axis {model} does not divide {n} devices "
                "(pass an explicit data size to use a subset)")
        data = n // model
    if data * model > n:
        raise ValueError(f"mesh ({data}, {model}) needs {data * model} "
                         f"devices, have {n}")
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:data * model])


def batch_axes(mesh) -> tuple:
    """Axes the batch dim shards over (pod included when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
