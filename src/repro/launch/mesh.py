"""Production mesh construction.

Single pod: (data=16, model=16) — 256 chips of TPU v5e.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the pod axis is pure
data parallelism over DCN (gradient all-reduce optionally 8-bit compressed,
see repro.runtime.compression), FSDP+TP live on the ICI axes.

Defined as functions (never module-level) so importing this module does not
touch jax device state — the dry-run sets XLA_FLAGS before first jax use.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Degenerate mesh for CPU tests."""
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    """Axes the batch dim shards over (pod included when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
