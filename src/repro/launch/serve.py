"""Serving driver: batched prefill + autoregressive decode with KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import build, get_config, list_archs, smoke_config


def generate(model, params, prompts, gen_len: int, cache_len: int = 0):
    """prompts (B, Tp) int32 -> (B, Tp+gen) greedy continuation."""
    cfg = model.cfg
    B, Tp = prompts.shape
    S = cache_len or (Tp + gen_len)
    cache = model.init_cache(B, S)

    decode = jax.jit(model.decode_step)
    tokens = prompts
    # teacher-forced prefill through the decode path (exercises the cache)
    last = None
    for i in range(Tp):
        last, cache = decode(params, cache, tokens[:, i], jnp.asarray(i, jnp.int32))
    out = [tokens]
    nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
    for i in range(Tp, Tp + gen_len):
        out.append(nxt[:, None])
        last, cache = decode(params, cache, nxt, jnp.asarray(i, jnp.int32))
        nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = generate(model, params, prompts, args.gen)
    dt = time.time() - t0
    toks = args.batch * (args.prompt_len + args.gen)
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    print(out[0])


if __name__ == "__main__":
    main()
