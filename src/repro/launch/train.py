"""End-to-end DP training driver: data pipeline -> BK private gradient ->
optimizer -> checkpoint/restart, with preemption + heartbeat guards.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
        --steps 50 --batch 8 --seq 64 --epsilon 3.0

Accepts a bare DPConfig or a named PrivacyPolicy preset (``--policy``; the
default 'auto' picks the arch's registered preset when one exists, e.g.
deepseek-moe-16b's expert/router/dense group-wise split). Before the first
step the driver can autotune the fused-kernel block sizes for the model's
actual tap shapes (``--autotune``, measured via kernels.dispatch.autotune and
pinned with override_blocks).

``--optimizer ftrl`` trains with momentum DP-FTRL: the policy's noise
mechanism is switched to binary-tree aggregation (depth sized to the run's
horizon), ``--restart-every N`` restarts both the optimizer anchor and the
noise tree every N steps, and ``--tree-completion`` applies the
honest-restart variance correction at each boundary.

Runs on whatever devices exist (CPU here, a pod via the same pjit path on
TPU — pass --mesh data,model sizes)."""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import run_state as rs
from repro.configs.base import TrainConfig
from repro.configs.registry import (build, get_config, get_policy, has_policy,
                                    list_archs, list_policies, smoke_config)
from repro.core.accounting import PrivacyLedger, budget_for
from repro.core.bk import DPConfig
from repro.core.policy import as_policy, resolve_policy
from repro.core.tape import Tape, parse_key
from repro.data.pipeline import Pipeline, PipelineConfig
from repro.launch import sharding as sh
from repro.launch.mesh import make_train_mesh
from repro.launch.steps import (TrainState, init_train_state,
                                make_train_step)
from repro.optim.optimizers import make_optimizer
from repro.optim.schedules import make_schedule
from repro.runtime.fault_injection import maybe_fault
from repro.runtime.fault_tolerance import (CheckpointManager, Heartbeat,
                                           PreemptionGuard)
from repro.utils.tree import flatten


def resolve_dp(arch: str, policy_name: str, mode: str, clipping: str,
               sigma: float, log=print):
    """--policy/--mode/--clipping/--sigma -> DPConfig or PrivacyPolicy."""
    if policy_name == "auto":
        policy_name = arch if has_policy(arch) else ""
    if not policy_name:
        return DPConfig(mode=mode, clipping=clipping, sigma=sigma)
    dp = get_policy(policy_name, mode=mode, sigma=sigma)
    if clipping != "automatic":
        log(f"note: --clipping {clipping} is IGNORED — the policy preset "
            f"{policy_name!r} defines clipping per group (pass --policy '' "
            "for a flat DPConfig)")
    log(f"policy preset {policy_name!r}: "
        + ", ".join(f"{g.name}({g.scope}{'' if g.trainable else ',frozen'}"
                    f" R={g.R})" for g in dp.groups))
    return dp


# ---------------------------------------------------------- autotune warmup
def _block_candidates(blocks: tuple, align: int = 8) -> list:
    """Candidate block tuples around the analytic choice: {x/2, x, 2x} per
    knob (aligned, deduped, cartesian, capped)."""
    axes = []
    for name, val in blocks:
        a = 128 if name == "block_v" else align
        vals = sorted({max(a, (val // 2) // a * a), val,
                       max(a, (val * 2) // a * a)})
        axes.append([(name, v) for v in vals])
    cands = [()]
    for axis in axes:
        cands = [c + (nv,) for c in cands for nv in axis]
    return cands[:16]


def _synth(struct, rng, vocab: int = 0):
    """Concrete array for one eval_shape leaf (ids get valid vocab range)."""
    if jnp.issubdtype(struct.dtype, jnp.integer):
        return jax.random.randint(rng, struct.shape, 0, max(vocab, 2),
                                  dtype=struct.dtype)
    if struct.dtype == jnp.bool_:
        return jnp.ones(struct.shape, jnp.bool_)
    return jax.random.normal(rng, struct.shape, struct.dtype)


def autotune_warmup(apply_fn, params, batch, dp, log=print) -> int:
    """Measured-autotune the fused kernels on THIS model's tap shapes, once,
    outside jit, and pin the winners via ``dispatch.override_blocks`` so
    every subsequent plan (train step, kernel_report) uses them.

    ROADMAP PR-1 follow-up: ``dispatch.autotune`` existed but nothing ran it
    automatically. Returns the number of (tap-shape, phase) cells tuned."""
    from repro.kernels import dispatch
    from repro.kernels import ops as kops

    policy = as_policy(dp)
    if not policy.use_kernels:
        return 0

    def shape_run(p, b):
        tape = Tape(None)
        apply_fn(p, b, tape)
        return tape.tap_zeros, tape.acts

    taps, acts = jax.eval_shape(shape_run, params, batch)
    flat_params = flatten(params)
    res = resolve_policy(policy, flat_params)

    runners = {  # (phase, kind, method) -> (ops fn, needs C, static knobs)
        ("norm", "mm", "ghost"): kops.ghost_norm_mm,
        ("norm", "mm", "direct"): kops.direct_norm_mm,
        ("norm", "emb", "ghost"): kops.ghost_norm_emb,
        ("norm", "moe", "direct"): kops.direct_norm_moe,
        ("grad", "mm", "direct"): kops.clipped_grad_mm,
        ("grad", "emb", "scatter"): kops.clipped_grad_emb,
        ("grad", "moe", "direct"): kops.clipped_grad_moe,
    }

    rng = jax.random.PRNGKey(0)
    tuned, seen = 0, set()
    for key in sorted(acts):
        path, kind, _ = parse_key(key)
        wpath = path + "/w"
        if wpath in res.frozen:
            continue
        method = res.method_for(wpath)
        a_struct = acts[key]["a"] if kind == "moe" else acts[key]
        ds_struct = taps[key]
        vocab = flat_params[wpath].shape[-2] if kind == "emb" else 0
        cell = (kind, tuple(a_struct.shape), tuple(ds_struct.shape), vocab,
                method)
        if cell in seen:
            continue
        seen.add(cell)

        B = ds_struct.shape[-4] if kind == "moe" else ds_struct.shape[-3]
        act = (dict(a=_synth(acts[key]["a"], rng),
                    mask=jnp.ones(acts[key]["mask"].shape,
                                  acts[key]["mask"].dtype))
               if kind == "moe" else _synth(acts[key], rng, vocab))
        ds = _synth(ds_struct, rng)
        C = jnp.ones((B,), jnp.float32)

        for phase in ("norm", "grad"):
            if phase == "norm":
                plan = dispatch.norm_plan(kind, a_struct.shape,
                                          ds_struct.shape, policy.mode,
                                          method)
                args = (act, ds)
            else:
                plan = dispatch.grad_plan(kind, a_struct.shape,
                                          ds_struct.shape, vocab)
                args = (act, C, ds)
            cands = _block_candidates(plan.blocks)
            fn = runners.get((phase, kind, plan.method))
            if plan.impl != "kernel" or fn is None or len(cands) <= 1:
                continue
            if phase == "grad" and kind == "emb":
                fn = functools.partial(fn, vocab=vocab)  # static under jit
            knobs = tuple(name for name, _ in plan.blocks)
            run = jax.jit(fn, static_argnames=knobs)
            try:
                best = dispatch.autotune(run, cands, *args)
            except ValueError as e:
                log(f"autotune {key}/{phase}: no candidate ran ({e})")
                continue
            dispatch.override_blocks(phase, kind, a_struct.shape,
                                     ds_struct.shape, best,
                                     mode=policy.mode, vocab=vocab,
                                     method=method)
            tuned += 1
            if best != plan.blocks:
                log(f"autotune {key}/{phase}: {dict(plan.blocks)} -> "
                    f"{dict(best)}")
    log(f"autotune warmup: {tuned} kernel cells tuned, pinned via "
        "override_blocks")
    return tuned


def train(model_cfg, tc: TrainConfig, dp, log=print,
          dataset_size: int = 0, target_epsilon: float = 0.0,
          delta: float = 1e-5, summary_out=None):
    model = build(model_cfg)
    if tc.tape or tc.tape_chunks:
        # --tape/--tape-chunks override whatever the DPConfig / preset set
        # (both config types carry the fields, so replace works on either)
        dp = dataclasses.replace(
            dp, **({"tape_policy": tc.tape} if tc.tape else {}),
            **({"tape_chunks": tc.tape_chunks} if tc.tape_chunks else {}))
    if tc.clipping_scope:
        # --clipping-scope re-scopes every trainable group (with_scope);
        # 'layer' turns each param path into its own clip unit and the BK
        # backward streams — one pass, nothing book-kept between phases
        from repro.core.policy import with_scope
        dp = with_scope(dp, tc.clipping_scope)
        log(f"clipping scope: {tc.clipping_scope}"
            + (" (per-path clip units; streamed one-pass backward)"
               if tc.clipping_scope == "layer" else ""))
    policy = as_policy(dp)
    if tc.tape or tc.tape_chunks:
        log(f"tape residency: policy={policy.tape_policy} "
            f"chunks={policy.tape_chunks}")
    if target_epsilon > 0 and dataset_size > 0 and policy.sigma == 0.0:
        # Tree-aggregation releases (DP-FTRL, or ANY policy configured with
        # noise='tree') get no subsampling amplification — the SGM curve
        # under-reports their epsilon, so calibrate against the tree
        # accountant whenever tree noise will actually run
        tree_release = tc.optimizer == "ftrl" or policy.noise == "tree"
        mechanism = "tree" if tree_release else "sgm"
        budget = budget_for(target_epsilon, delta, tc.global_batch,
                            dataset_size,
                            tc.steps * tc.global_batch / dataset_size,
                            mechanism=mechanism,
                            restart_every=(tc.restart_every
                                           or policy.noise_restart_every))
        dp = dataclasses.replace(dp, sigma=budget.sigma)
        log(f"calibrated sigma={budget.sigma:.3f} for "
            f"eps={budget.epsilon:.2f} ({mechanism} accountant)")
        if any(g.sigma_scale != 1.0 for g in policy.groups):
            log("WARNING: sigma was calibrated with the FLAT single-sigma "
                "accountant, but this policy sets per-group sigma_scale — "
                "the true joint-bound epsilon differs (larger when any "
                "scale < 1). Re-check with compute_epsilon("
                "resolved.noise_multipliers(), ...) (README 'Accounting "
                "caveats').")

    if tc.optimizer != "ftrl" and (tc.restart_every or tc.tree_completion
                                   or tc.ftrl_momentum):
        # silently ignoring these would leave the user believing they
        # configured tree restarts while plain gaussian noise runs
        raise ValueError(
            "--restart-every/--tree-completion/--ftrl-momentum are DP-FTRL "
            f"knobs; pass --optimizer ftrl (got {tc.optimizer!r})")
    if tc.tree_completion and tc.restart_every <= 0:
        raise ValueError("--tree-completion corrects the noise at epoch "
                         "boundaries; pass --restart-every N (> 0) with it")
    if tc.optimizer == "ftrl" and tc.lr_schedule != "constant":
        log(f"WARNING: FTRL rescales the WHOLE gradient prefix by the "
            f"current lr — a decaying schedule ({tc.lr_schedule!r}) drags "
            "the iterate back toward its anchor and undoes most of "
            "training. Use lr_schedule='constant' (the CLI driver forces "
            "it for --optimizer ftrl).")
    ftrl_restart = tc.restart_every
    if tc.optimizer == "ftrl" and policy.mode != "nonprivate":
        # FTRL consumes the NOISY GRADIENT PREFIX: switch the policy to the
        # tree-aggregation mechanism with depth sized to the actual horizon
        # so each add() pays only what it needs. A policy that already
        # configures tree noise keeps its own knobs (never silently
        # overridden); either way the optimizer anchor and the noise tree
        # must restart at the SAME boundary, so conflicts are an error.
        from repro.core.noise import next_pow2
        pol = as_policy(dp)
        pol_tree = pol.noise == "tree"
        if pol_tree and pol.noise_restart_every and tc.restart_every and \
                pol.noise_restart_every != tc.restart_every:
            raise ValueError(
                f"policy sets noise_restart_every={pol.noise_restart_every} "
                f"but --restart-every={tc.restart_every}: the FTRL anchor "
                "and the noise tree must restart together")
        ftrl_restart = tc.restart_every or \
            (pol.noise_restart_every if pol_tree else 0)
        completion = tc.tree_completion or \
            (pol.noise_completion if pol_tree else False)
        horizon = ftrl_restart if ftrl_restart > 0 else tc.steps
        depth = (pol.noise_depth if pol_tree and pol.noise_depth
                 else max(next_pow2(horizon).bit_length(), 1))
        dp = dataclasses.replace(pol, noise="tree", noise_depth=depth,
                                 noise_restart_every=ftrl_restart,
                                 noise_completion=completion)
        policy = dp
        log(f"DP-FTRL: tree noise depth={policy.noise_depth} "
            f"restart_every={ftrl_restart or 'never'} "
            f"completion={completion}")

    # validate the tree horizon upfront for EVERY optimizer: inside the
    # jitted step the index is traced, so the mechanism's own concrete-step
    # guard can never fire — past 2^depth - 1 the prefix would collapse and
    # increments would subtract released noise with no error
    final_policy = as_policy(dp)
    if final_policy.noise == "tree" and final_policy.noise_depth and \
            not final_policy.noise_restart_every and \
            tc.steps > (1 << final_policy.noise_depth) - 1:
        raise ValueError(
            f"noise_depth={final_policy.noise_depth} covers only "
            f"{(1 << final_policy.noise_depth) - 1} steps but the run has "
            f"{tc.steps}; raise noise_depth or set restarts")
    # surface mechanism config errors before init; the bound instance also
    # carries the restorable noise state the RunState checkpoint persists
    mech = final_policy.mechanism()

    opt_kw = ({"momentum": tc.ftrl_momentum,
               "restart_every": ftrl_restart}
              if tc.optimizer == "ftrl" else {})
    opt = make_optimizer(tc.optimizer,
                         make_schedule(tc.lr_schedule, tc.lr, tc.warmup, tc.steps),
                         weight_decay=tc.weight_decay, **opt_kw)
    pipe = Pipeline(model_cfg, PipelineConfig(tc.global_batch, tc.seq_len,
                                              seed=tc.seed))

    guard = PreemptionGuard()

    def on_stall(report):
        # a hung step can't be checkpointed from here (its state is inside
        # the collective), but requesting a stop means the loop — if it
        # ever returns — force-saves before exit instead of running on
        log(report.describe() + "; requesting graceful stop + checkpoint")
        guard.request_stop()

    hb = Heartbeat(timeout_s=600.0, on_stall=on_stall)
    mgr = (CheckpointManager(tc.checkpoint_dir, every=tc.checkpoint_every,
                             keep=tc.keep_checkpoints)
           if tc.checkpoint_dir else None)

    # ---- privacy ledger (absolute steps accounted, resumed verbatim) --------
    mech_kind = "tree" if final_policy.noise == "tree" else "sgm"
    sample_rate = (tc.global_batch / dataset_size if dataset_size > 0
                   else 1.0)
    ledger_restart = ftrl_restart or final_policy.noise_restart_every
    participations = (max(1, math.ceil(tc.steps * tc.global_batch
                                       / dataset_size))
                      if dataset_size > 0 else 1)
    ledger_kw = dict(sigma=float(final_policy.sigma),
                     sample_rate=sample_rate, mechanism=mech_kind,
                     restart_every=ledger_restart,
                     participations=participations)
    ledger = PrivacyLedger()
    fingerprint = rs.config_fingerprint(tc, final_policy, ftrl_restart)

    # ---- init or resume -----------------------------------------------------
    start = 0
    params = model.init(jax.random.PRNGKey(tc.seed))
    opt_state = opt.init(params)
    base_rng = jax.random.PRNGKey(tc.seed + 1)
    if mgr is not None:
        state0, step0, meta0 = mgr.resume(template={"params": params,
                                                    "opt": opt_state,
                                                    "step": np.asarray(0),
                                                    "rng": base_rng})
        if state0 is not None:
            # validates noise/pipeline/config against the checkpoint and
            # raises on privacy-critical drift; restores the spent ledger
            ledger = rs.check_resume(meta0, mech, pipe, fingerprint, log=log)
            params, opt_state = state0["params"], state0["opt"]
            base_rng = state0["rng"]
            start = step0 + 1
            log(f"resumed from step {step0} "
                f"(ledger covers {ledger.recorded_to} steps)")

    # ---- warmup: measured kernel autotune on the real tap shapes ------------
    if tc.autotune == "on" or (tc.autotune == "auto"
                               and jax.default_backend() != "cpu"):
        autotune_warmup(model.apply, params, pipe.batch(0), dp, log=log)

    # ---- the mesh-native donated step ---------------------------------------
    # One jitted (state, batch) -> (state, loss): explicit in/out shardings
    # from the partition-spec tables, the whole TrainState donated, BK
    # lowered batch-sharded with shard-local noise (launch.steps).
    mesh = make_train_mesh(tc.mesh_data, tc.mesh_model)
    if len(mesh.devices.flat) > 1:
        log(f"mesh {dict(mesh.shape)} over {mesh.devices.size} devices")
    step_fn, state_sh, batch_sh = make_train_step(
        model.apply, params, opt, tc.optimizer, dp, tc.microbatch, mesh,
        pipe.batch(0))
    jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
    # base_rng is the CHECKPOINTED key on resume: per-step keys fold the
    # absolute step into it, so restoring it replays the interrupted run's
    # exact noise sequence (the bitwise-restart guarantee)
    state = init_train_state(params, opt_state, start, base_rng, state_sh)

    def snapshot(s: TrainState, step: int) -> dict:
        return {"params": s.params, "opt": s.opt_state,
                "step": np.asarray(step), "rng": s.rng}

    def run_meta() -> dict:
        return rs.pack_meta(mech, ledger, pipe, fingerprint)

    # losses stay on device; the buffer drains every log_every steps and at
    # exit — no step blocks on a device->host sync
    losses, pending = [], []
    log_every = max(1, tc.log_every)
    t_flush = time.time()

    def flush(step: int):
        nonlocal t_flush
        if not pending:
            return
        n = len(pending)
        losses.extend(float(x) for x in jax.device_get(pending))
        pending.clear()
        dt = (time.time() - t_flush) / n
        t_flush = time.time()
        log(f"step {step:5d} loss {losses[-1]:.4f} ({dt:.2f}s/step over "
            f"last {n})")

    with mesh:
        for step in range(start, tc.steps):
            maybe_fault("step", step)  # crash/preemption injection (tests)
            batch = jax.device_put(pipe.batch(step), batch_sh)
            state, loss = jitted(state, batch)
            pending.append(loss)
            hb.beat(step)
            # every executed absolute step is accounted exactly once —
            # resumed replays are no-ops (ledger.record_to is idempotent)
            ledger.record_to(step + 1, **ledger_kw)
            saved = (mgr.maybe_save(step, snapshot(state, step),
                                    meta=run_meta())
                     if mgr is not None else False)
            if guard.should_stop():
                if mgr is not None and not saved:
                    mgr.maybe_save(step, snapshot(state, step), force=True,
                                   meta=run_meta())
                flush(step)
                log(f"preempted at step {step}; checkpoint saved")
                break
            if (step + 1) % log_every == 0 or step == tc.steps - 1:
                flush(step)
    flush(tc.steps - 1)
    if mgr is not None:
        mgr.wait()
    hb.close()

    epsilon = None
    if final_policy.mode != "nonprivate" and ledger.recorded_to > 0:
        epsilon = ledger.epsilon(delta)
        log(f"privacy spent: eps={epsilon:.4g} (delta={delta:g}) over "
            f"{ledger.recorded_to} accounted steps "
            f"[{mech_kind}{' restarts' if ledger_restart else ''}]")
    if summary_out is not None:
        summary_out.update({
            "steps_done": ledger.recorded_to,
            "resumed_from": start,
            "epsilon": epsilon,
            "delta": delta,
            "params_sha256": rs.params_digest(state.params),
            "ledger": ledger.to_json(),
        })
    return jax.device_get(state.params), losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw",
                    help="sgd | adamw | lamb | adafactor | ftrl (DP-FTRL: "
                         "tree-aggregation noise, prefix-sum iterate)")
    ap.add_argument("--ftrl-momentum", type=float, default=0.0,
                    help="DP-FTRL momentum over noisy gradient prefixes")
    ap.add_argument("--restart-every", type=int, default=0,
                    help="DP-FTRL epoch restart period in steps (0 = never); "
                         "restarts the optimizer anchor AND the noise tree")
    ap.add_argument("--tree-completion", action="store_true",
                    help="Honaker completion: advance each epoch's tree to "
                         "the next power of two before restarting")
    ap.add_argument("--mode", default="bk-mixopt")
    ap.add_argument("--clipping", default="automatic")
    ap.add_argument("--sigma", type=float, default=0.0)
    ap.add_argument("--epsilon", type=float, default=0.0)
    ap.add_argument("--dataset-size", type=int, default=50000)
    ap.add_argument("--policy", default="auto",
                    help="PrivacyPolicy preset name; 'auto' = the arch's "
                         f"registered preset (known: {list_policies()}), "
                         "'' = flat DPConfig")
    ap.add_argument("--autotune", choices=["auto", "on", "off"],
                    default="auto",
                    help="measured kernel-block autotune at startup "
                         "(auto = on for non-CPU backends)")
    ap.add_argument("--tape", default="",
                    choices=["", "native", "bf16", "int8", "recompute",
                             "auto"],
                    help="tape residency for book-kept tap state between BK "
                         "phases 2-3: hold native, compressed (bf16/int8), "
                         "re-derive in phase 3 (recompute), or let the "
                         "dispatch planner pick per tap (auto); '' keeps "
                         "the policy preset's choice")
    ap.add_argument("--tape-chunks", type=int, default=0,
                    help="phase-3 re-derivation chunk count for recompute "
                         "taps (0 keeps the policy's)")
    ap.add_argument("--clipping-scope", default="",
                    choices=["", "flat", "group", "layer"],
                    help="re-scope every trainable group's clipping norm: "
                         "flat (one pool), group (per policy group), layer "
                         "(each param path its own clip unit — the BK "
                         "backward streams in one pass with nothing "
                         "book-kept); '' keeps the preset's scopes")
    ap.add_argument("--mesh", default="",
                    help="data,model axis sizes for the train mesh "
                         "(e.g. 4,2); default: all devices on 'data'")
    ap.add_argument("--log-every", type=int, default=10,
                    help="loss log + device->host flush period in steps")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--out", default="",
                    help="write a json run summary (steps done, epsilon, "
                         "params sha256, ledger) — the CI crash/resume "
                         "stage compares these across runs")
    args = ap.parse_args()

    mesh_data, mesh_model = 0, 1
    if args.mesh:
        try:
            mesh_data, mesh_model = (int(x) for x in args.mesh.split(","))
        except ValueError:
            ap.error(f"--mesh wants 'data,model' ints, got {args.mesh!r}")

    mc = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mc = mc.with_(dtype="float32", param_dtype="float32") if args.smoke else mc
    tc = TrainConfig(global_batch=args.batch, microbatch=args.microbatch,
                     seq_len=args.seq, steps=args.steps, lr=args.lr,
                     optimizer=args.optimizer,
                     # FTRL rescales the whole prefix by lr_t: decay would
                     # pull the iterate back toward the anchor
                     lr_schedule=("constant" if args.optimizer == "ftrl"
                                  else TrainConfig.lr_schedule),
                     ftrl_momentum=args.ftrl_momentum,
                     restart_every=args.restart_every,
                     tree_completion=args.tree_completion,
                     policy=args.policy, autotune=args.autotune,
                     tape=args.tape, tape_chunks=args.tape_chunks,
                     clipping_scope=args.clipping_scope,
                     mesh_data=mesh_data, mesh_model=mesh_model,
                     log_every=args.log_every,
                     checkpoint_dir=args.ckpt_dir,
                     checkpoint_every=args.ckpt_every)
    dp = resolve_dp(args.arch, args.policy, args.mode, args.clipping,
                    args.sigma)
    summary = {} if args.out else None
    train(mc, tc, dp, dataset_size=args.dataset_size,
          target_epsilon=args.epsilon, summary_out=summary)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=2)
        print(f"summary written to {args.out}")


if __name__ == "__main__":
    main()
