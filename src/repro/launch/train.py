"""End-to-end DP training driver: data pipeline -> BK private gradient ->
optimizer -> checkpoint/restart, with preemption + heartbeat guards.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
        --steps 50 --batch 8 --seq 64 --epsilon 3.0

Runs on whatever devices exist (CPU here, a pod via the same pjit path on
TPU — pass --mesh data,model sizes)."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.configs.registry import build, get_config, list_archs, smoke_config
from repro.core.accounting import budget_for
from repro.core.bk import DPConfig
from repro.data.pipeline import Pipeline, PipelineConfig
from repro.launch import sharding as sh
from repro.optim.accumulate import accumulated_private_grad
from repro.optim.optimizers import make_optimizer
from repro.optim.schedules import make_schedule
from repro.runtime.fault_tolerance import (CheckpointManager, Heartbeat,
                                           PreemptionGuard)


def train(model_cfg, tc: TrainConfig, dp: DPConfig, log=print,
          dataset_size: int = 0, target_epsilon: float = 0.0,
          delta: float = 1e-5):
    model = build(model_cfg)
    if target_epsilon > 0 and dataset_size > 0 and dp.sigma == 0.0:
        budget = budget_for(target_epsilon, delta, tc.global_batch,
                            dataset_size, tc.steps * tc.global_batch / dataset_size)
        dp = DPConfig(**{**dp.__dict__, "sigma": budget.sigma})
        log(f"calibrated sigma={budget.sigma:.3f} for eps={budget.epsilon:.2f}")

    opt = make_optimizer(tc.optimizer,
                         make_schedule(tc.lr_schedule, tc.lr, tc.warmup, tc.steps),
                         weight_decay=tc.weight_decay)
    pipe = Pipeline(model_cfg, PipelineConfig(tc.global_batch, tc.seq_len,
                                              seed=tc.seed))

    guard = PreemptionGuard()
    hb = Heartbeat(timeout_s=600.0)
    mgr = (CheckpointManager(tc.checkpoint_dir, every=tc.checkpoint_every,
                             keep=tc.keep_checkpoints)
           if tc.checkpoint_dir else None)

    # ---- init or resume -----------------------------------------------------
    start = 0
    params = model.init(jax.random.PRNGKey(tc.seed))
    opt_state = opt.init(params)
    if mgr is not None:
        state, step = mgr.resume(template={"params": params,
                                           "opt": opt_state,
                                           "step": np.asarray(0)})
        if state is not None:
            params, opt_state = state["params"], state["opt"]
            start = int(state["step"]) + 1
            log(f"resumed from step {start - 1}")

    @jax.jit
    def step_fn(p, o, i, batch, rng):
        if dp.mode == "nonprivate":
            from repro.core.engine import make_grad_fn
            grads, aux = make_grad_fn(model.apply, dp)(p, batch, rng)
        else:
            grads, aux = accumulated_private_grad(model.apply, p, batch, rng,
                                                  dp, tc.microbatch)
        new_p, new_o = opt.update(grads, o, p, i)
        return new_p, new_o, aux["loss"]

    losses = []
    rng0 = jax.random.PRNGKey(tc.seed + 1)
    for step in range(start, tc.steps):
        t0 = time.time()
        batch = pipe.batch(step)
        rng = jax.random.fold_in(rng0, step)
        params, opt_state, loss = step_fn(params, opt_state,
                                          jnp.asarray(step), batch, rng)
        losses.append(float(loss))
        hb.beat(step)
        if mgr is not None:
            mgr.maybe_save(step, {"params": params, "opt": opt_state,
                                  "step": np.asarray(step)})
        if guard.should_stop():
            if mgr is not None:
                mgr.maybe_save(step, {"params": params, "opt": opt_state,
                                      "step": np.asarray(step)}, force=True)
            log(f"preempted at step {step}; checkpoint saved")
            break
        if step % 10 == 0 or step == tc.steps - 1:
            log(f"step {step:5d} loss {float(loss):.4f} "
                f"({time.time() - t0:.2f}s)")
    if mgr is not None:
        mgr.wait()
    hb.close()
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--mode", default="bk-mixopt")
    ap.add_argument("--clipping", default="automatic")
    ap.add_argument("--sigma", type=float, default=0.0)
    ap.add_argument("--epsilon", type=float, default=0.0)
    ap.add_argument("--dataset-size", type=int, default=50000)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()

    mc = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mc = mc.with_(dtype="float32", param_dtype="float32") if args.smoke else mc
    tc = TrainConfig(global_batch=args.batch, microbatch=args.microbatch,
                     seq_len=args.seq, steps=args.steps, lr=args.lr,
                     optimizer=args.optimizer,
                     checkpoint_dir=args.ckpt_dir,
                     checkpoint_every=args.ckpt_every)
    dp = DPConfig(mode=args.mode, clipping=args.clipping, sigma=args.sigma)
    train(mc, tc, dp, dataset_size=args.dataset_size,
          target_epsilon=args.epsilon)


if __name__ == "__main__":
    main()
