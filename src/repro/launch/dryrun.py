import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory / cost / collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod] [--force]

The XLA_FLAGS line above MUST run before any other import touches jax: the
dry-run (and only the dry-run) builds the 512-chip mesh out of host devices.
Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json and feed
EXPERIMENTS.md §Dry-run and the §Roofline table."""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs.base import SHAPES            # noqa: E402
from repro.configs.registry import get_config, list_archs  # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402
from repro.launch.steps import plan_cell, skip_reason       # noqa: E402
from repro.utils.hlo import analyze_hlo, xla_cost_analysis  # noqa: E402

OUT_ROOT = os.path.join(os.path.dirname(__file__), "../../../experiments/dryrun")


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             force: bool = False, dp_mode: str = "bk",
             clipping_scope: str = "") -> dict:
    os.makedirs(out_dir, exist_ok=True)
    scope_tag = f"__scope_{clipping_scope}" if clipping_scope else ""
    out_path = os.path.join(out_dir, f"{arch}__{shape}{scope_tag}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "dp_mode": dp_mode, "status": "ok"}
    if clipping_scope:
        rec["clipping_scope"] = clipping_scope
    cfg = get_config(arch)
    reason = skip_reason(cfg, SHAPES[shape])
    if reason:
        rec.update(status="skip", reason=reason)
    else:
        try:
            mesh = make_production_mesh(multi_pod=multi_pod)
            t0 = time.time()
            plan = plan_cell(arch, shape, mesh, clipping_scope=clipping_scope)
            lowered = plan.lower()
            rec["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)
            ma = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "peak_bytes": getattr(ma, "peak_memory_in_bytes", 0),
            }
            ca = xla_cost_analysis(compiled)
            rec["cost"] = {k: ca[k] for k in ("flops", "bytes accessed")
                           if k in ca}
            # trip-aware totals (XLA cost_analysis counts scan bodies once)
            hla = analyze_hlo(compiled.as_text())
            rec["hlo"] = {"flops": hla["flops"],
                          "traffic_bytes": hla["traffic_bytes"]}
            rec["collectives"] = hla["collectives"]
            rec["note"] = plan.note
            rec["kind"] = plan.kind
        except Exception as e:  # a failing cell is a bug to fix, keep record
            rec.update(status="error", error=f"{type(e).__name__}: {e}",
                       trace=traceback.format_exc()[-4000:])
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--dp-mode", default="bk")
    ap.add_argument("--clipping-scope", default="",
                    choices=["", "flat", "group", "layer"],
                    help="re-scope trainable groups before planning (layer "
                         "plans the streamed one-pass backward; results land "
                         "in <arch>__<shape>__scope_<s>.json)")
    args = ap.parse_args()

    mesh_tag = "multipod_2x16x16" if args.multipod else "singlepod_16x16"
    out_dir = os.path.normpath(os.path.join(OUT_ROOT, mesh_tag))
    cells = ([(args.arch, args.shape)] if args.arch and args.shape else
             [(a, s) for a in list_archs() for s in sorted(SHAPES)])
    if not args.all and not (args.arch and args.shape):
        ap.error("pass --arch+--shape or --all")

    n_ok = n_skip = n_err = 0
    for arch, shape in cells:
        rec = run_cell(arch, shape, args.multipod, out_dir, args.force,
                       args.dp_mode, clipping_scope=args.clipping_scope)
        tag = rec["status"]
        n_ok += tag == "ok"
        n_skip += tag == "skip"
        n_err += tag == "error"
        if tag == "ok":
            mb = rec["memory"]
            print(f"[{tag}] {arch:22s} {shape:12s} "
                  f"args={mb['argument_bytes']/2**30:.2f}GiB "
                  f"temp={mb['temp_bytes']/2**30:.2f}GiB "
                  f"flops/dev={rec['hlo']['flops']:.3g} "
                  f"traffic={rec['hlo']['traffic_bytes']/2**30:.1f}GiB "
                  f"coll={rec['collectives']['total']/2**20:.1f}MiB "
                  f"(lower {rec.get('lower_s')}s compile {rec.get('compile_s')}s)",
                  flush=True)
        elif tag == "skip":
            print(f"[skip] {arch:22s} {shape:12s} {rec['reason'][:80]}", flush=True)
        else:
            print(f"[ERR ] {arch:22s} {shape:12s} {rec['error'][:160]}", flush=True)
    print(f"done: {n_ok} ok, {n_skip} skip, {n_err} error")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
