"""Dense / MoE decoder-only transformer with GQA, rope, qk-norm, qkv-bias —
covers llama3 / qwen2 / qwen2.5 / qwen3 / deepseek-moe / moonshot and the
InternVL backbone. Layers run under lax.scan (stacked params) so the HLO is
depth-independent; every parametrized op routes through the Tape.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.tape import Tape, fix_scan_params, subtape_run
from repro.models import layers as L
from repro.models import moe as M
from repro.models.attention import (decode_attention, multihead_attention,
                                    update_cache)

NORMS = {"rmsnorm": (L.rmsnorm_init, L.rmsnorm),
         "layernorm": (L.layernorm_init, L.layernorm)}


# ------------------------------------------------------------------ attention
def attn_init(rng, cfg: ModelConfig):
    d, H, K, h = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(rng, 4)
    dt = jnp.dtype(cfg.param_dtype)
    p = {"qkv": L.linear_init(ks[0], d, (H + 2 * K) * h, dt, bias=cfg.qkv_bias),
         "o": L.linear_init(ks[1], H * h, d, dt)}
    if cfg.qk_norm:
        p["qn"] = L.rmsnorm_init(ks[2], h, dt)
        p["kn"] = L.rmsnorm_init(ks[3], h, dt)
    return p


def _qkv(p, tape, x, cfg, cos, sin, positions=None):
    B, T = x.shape[0], x.shape[1]
    H, K, h = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    qkv = L.linear(tape, "qkv", p["qkv"], x)
    q, k, v = jnp.split(qkv, [H * h, (H + K) * h], axis=-1)
    q = q.reshape(B, T, H, h)
    k = k.reshape(B, T, K, h)
    v = v.reshape(B, T, K, h)
    if cfg.qk_norm:
        q = L.rmsnorm(p["qn"], q)
        k = L.rmsnorm(p["kn"], k)
    if cos is not None:
        q = L.apply_rope(q, cos, sin, positions)
        k = L.apply_rope(k, cos, sin, positions)
    return q, k, v


def attn_apply(p, tape, x, cfg: ModelConfig, cos, sin, *, causal=True, window=0):
    B, T = x.shape[0], x.shape[1]
    q, k, v = _qkv(p, tape, x, cfg, cos, sin)
    if cfg.seq_shard_attn:
        # context parallelism: when head count doesn't divide the TP axis,
        # shard the QUERY sequence over 'model' instead (full KV gathered —
        # KV is small under GQA). Each rank does T/16 queries x all heads:
        # 1/16th the compute/memory of head-replicated attention.
        from jax.sharding import PartitionSpec as P
        q = jax.lax.with_sharding_constraint(q, P(None, "model", None, None))
        out = multihead_attention(q, k, v, causal=causal, window=window)
        out = jax.lax.with_sharding_constraint(out, P(None, "model", None, None))
    else:
        out = multihead_attention(q, k, v, causal=causal, window=window,
                                  chunk=cfg.attn_chunk)
    return L.linear(tape, "o", p["o"], out.reshape(B, T, -1))


def attn_decode(p, tape, x, cfg: ModelConfig, cos, sin, cache, pos, window=0):
    """x (B,1,d); cache {'k','v'} (B,S,K,h); pos scalar. -> out, cache."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _qkv(p, tape, x, cfg, cos, sin, positions)
    ck, cv = update_cache(cache["k"], cache["v"], k, v, pos)
    out = decode_attention(q, ck, cv, pos, window=window)
    out = L.linear(tape, "o", p["o"], out.reshape(B, 1, -1))
    return out, {"k": ck, "v": cv}


# ------------------------------------------------------------------------ mlp
def mlp_init(rng, cfg: ModelConfig, d_ff=0):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(rng)
    mult = 2 if cfg.act == "swiglu" else 1
    return {"up": L.linear_init(k1, d, mult * ff, dt),
            "down": L.linear_init(k2, ff, d, dt)}


def mlp_apply(p, tape, x, cfg: ModelConfig):
    u = L.linear(tape, "up", p["up"], x)
    if cfg.act == "swiglu":
        g, u = jnp.split(u, 2, axis=-1)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(u)
    return L.linear(tape, "down", p["down"], h)


# --------------------------------------------------------------- dense block
def dense_block_init(rng, cfg: ModelConfig, use_moe=False):
    ks = jax.random.split(rng, 4)
    ninit = NORMS[cfg.norm][0]
    dt = jnp.dtype(cfg.param_dtype)
    p = {"ln1": ninit(ks[0], cfg.d_model, dt),
         "attn": attn_init(ks[1], cfg),
         "ln2": ninit(ks[2], cfg.d_model, dt)}
    p["mlp"] = M.moe_init(ks[3], cfg) if use_moe else mlp_init(ks[3], cfg)
    return p


def dense_block_apply(p, tape, x, cfg: ModelConfig, cos, sin, *, causal=True,
                      window=0, use_moe=False):
    norm = NORMS[cfg.norm][1]
    if cfg.seq_parallel:
        # Megatron-SP: the residual stream stays sequence-sharded over
        # 'model'; TP matmul outputs reduce-scatter back to it instead of
        # all-reducing the full activation (halves the dominant wire term)
        from jax.sharding import PartitionSpec as P
        x = jax.lax.with_sharding_constraint(x, P(None, "model", None))
    with tape.scope("attn"):
        x = x + attn_apply(p["attn"], tape, norm(p["ln1"], x), cfg, cos, sin,
                           causal=causal, window=window)
    with tape.scope("mlp"):
        h = norm(p["ln2"], x)
        x = x + (M.moe_apply(p["mlp"], tape, h, cfg) if use_moe
                 else mlp_apply(p["mlp"], tape, h, cfg))
    if cfg.seq_parallel:
        from jax.sharding import PartitionSpec as P
        x = jax.lax.with_sharding_constraint(x, P(None, "model", None))
    return x


def dense_block_decode(p, tape, x, cfg: ModelConfig, cos, sin, cache, pos,
                       window=0, use_moe=False):
    norm = NORMS[cfg.norm][1]
    a, new_cache = attn_decode(p["attn"], tape, norm(p["ln1"], x), cfg, cos,
                               sin, cache, pos, window)
    x = x + a
    h = norm(p["ln2"], x)
    x = x + (M.moe_apply(p["mlp"], tape, h, cfg) if use_moe
             else mlp_apply(p["mlp"], tape, h, cfg))
    return x, new_cache


# ------------------------------------------------------------------ LM model
class TransformerLM:
    """Decoder-only LM. families: dense, moe, vlm (dense backbone + patch
    projector)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.use_moe = cfg.family == "moe"

    # ------------------------------------------------------------------ init
    def init(self, rng):
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        ks = jax.random.split(rng, 8)
        n_scan = cfg.n_layers - cfg.first_k_dense
        params = {
            "embed": L.embedding_init(ks[0], cfg.vocab, cfg.d_model, dt),
            "final_norm": NORMS[cfg.norm][0](ks[1], cfg.d_model, dt),
            # mu-P-style small readout: standard-scale head init puts ~1
            # nat of logit-variance penalty on the initial loss, which
            # swamps the first hundred steps' progress; 0.1x starts the
            # model at ~ln(vocab) so early learning is visible in the loss
            "head": L.linear_init(ks[2], cfg.d_model, cfg.vocab, dt,
                                  scale=0.1 / math.sqrt(cfg.d_model)),
        }
        if cfg.first_k_dense:
            dense_keys = jax.random.split(ks[3], cfg.first_k_dense)
            for i in range(cfg.first_k_dense):
                params[f"dense0_{i}"] = dense_block_init(dense_keys[i], cfg,
                                                         use_moe=False)
        block_keys = jax.random.split(ks[4], n_scan)
        params["blocks"] = jax.vmap(
            lambda k: dense_block_init(k, cfg, use_moe=self.use_moe))(block_keys)
        if cfg.family == "vlm":
            params["projector"] = L.linear_init(ks[5], cfg.vit_dim,
                                                cfg.d_model, dt, bias=True)
        return params

    # --------------------------------------------------------------- helpers
    def _rope(self, max_t):
        return L.rope_freqs(self.cfg.hd, max_t, self.cfg.rope_theta)

    def _scan_blocks(self, params, tape, x, cos, sin, name="blocks",
                     use_moe=None):
        cfg = self.cfg
        use_moe = self.use_moe if use_moe is None else use_moe
        sub = tape.subtaps(name)
        tapped = sub is not None

        def block(p_l, t_l, xx):
            return subtape_run(
                lambda pp, tp: dense_block_apply(pp, tp, xx, cfg, cos, sin,
                                                 use_moe=use_moe),
                p_l, t_l, collect=tape.collect)

        run = jax.checkpoint(block) if cfg.remat else block

        def body(xx, xs):
            p_l, taps_l = xs
            out, aux = run(p_l, taps_l if tapped else None, xx)
            return out, aux

        blocks = fix_scan_params(params[name], tapped)
        x, (acts, tapz) = jax.lax.scan(body, x, (blocks, sub if tapped else {}))
        tape.merge_stacked(name, acts, tapz)
        return x

    def _unscanned_blocks(self, params, tape, x, cos, sin, name, n, use_moe):
        for i in range(n):
            with tape.scope(f"{name}_{i}"):
                x = dense_block_apply(params[f"{name}_{i}"], tape, x, self.cfg,
                                      cos, sin, use_moe=use_moe)
        return x

    def _trunk(self, params, tape, x, max_t):
        cfg = self.cfg
        cos, sin = self._rope(max_t)
        if cfg.first_k_dense:
            x = self._unscanned_blocks(params, tape, x, cos, sin, "dense0",
                                       cfg.first_k_dense, use_moe=False)
        x = self._scan_blocks(params, tape, x, cos, sin)
        return NORMS[cfg.norm][1](params["final_norm"], x)

    # ----------------------------------------------------------------- train
    def apply(self, params, batch, tape: Tape):
        """batch {'tokens': (B,T) [, 'patches': (B,Np,vit_dim), 'mask']}
        -> per-sample losses (B,)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = L.embedding(tape, "embed", params["embed"], tokens)
        n_prefix = 0
        if cfg.family == "vlm":
            pp = L.linear(tape, "projector", params["projector"],
                          batch["patches"].astype(x.dtype))
            x = jnp.concatenate([pp, x], axis=1)
            n_prefix = pp.shape[1]
        x = self._trunk(params, tape, x, x.shape[1])
        logits = L.linear(tape, "head", params["head"], x)
        logits = logits[:, n_prefix:, :]
        labels = tokens[:, 1:]
        mask = batch.get("mask")
        mask = mask[:, 1:] if mask is not None else None
        return L.lm_per_sample_loss(logits[:, :-1], labels, mask)

    # --------------------------------------------------------------- serving
    def prefill(self, params, tokens, patches=None):
        """Serving prefill -> last-position logits (B,V)."""
        tape = Tape.null()
        x = L.embedding(tape, "embed", params["embed"], tokens)
        if patches is not None:
            pp = L.linear(tape, "projector", params["projector"],
                          patches.astype(x.dtype))
            x = jnp.concatenate([pp, x], axis=1)
        x = self._trunk(params, tape, x, x.shape[1])
        return L.linear(tape, "head", params["head"], x[:, -1:, :])[:, 0]

    def init_cache(self, B, S, dtype=None):
        cfg = self.cfg
        dt = jnp.dtype(dtype or cfg.dtype)
        K, h, Ltot = cfg.n_kv_heads, cfg.hd, cfg.n_layers
        kv = lambda n: {"k": jnp.zeros((n, B, S, K, h), dt),
                        "v": jnp.zeros((n, B, S, K, h), dt)}
        cache = {"blocks": kv(Ltot - cfg.first_k_dense)}
        for i in range(cfg.first_k_dense):
            cache[f"dense0_{i}"] = {"k": jnp.zeros((B, S, K, h), dt),
                                    "v": jnp.zeros((B, S, K, h), dt)}
        return cache

    def decode_step(self, params, cache, tokens, pos):
        """tokens (B,) int32; pos scalar int32 (index being written).
        -> logits (B,V), new cache."""
        cfg = self.cfg
        tape = Tape.null()
        cos, sin = self._rope(cache["blocks"]["k"].shape[2])
        x = L.embedding(tape, "embed", params["embed"], tokens[:, None])
        new_cache = {}
        for i in range(cfg.first_k_dense):
            with tape.scope(f"dense0_{i}"):
                x, c_l = dense_block_decode(params[f"dense0_{i}"], tape, x,
                                            cfg, cos, sin,
                                            cache[f"dense0_{i}"], pos,
                                            use_moe=False)
            new_cache[f"dense0_{i}"] = c_l

        def body(xx, xs):
            p_l, c_l = xs
            out, c_l = dense_block_decode(p_l, tape, xx, cfg, cos, sin, c_l,
                                          pos, use_moe=self.use_moe)
            return out, c_l

        x, nc = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = nc
        x = NORMS[cfg.norm][1](params["final_norm"], x)
        logits = L.linear(tape, "head", params["head"], x)
        return logits[:, 0, :], new_cache
