"""Attention math (param-free; projections live in the blocks).

Supports GQA, causal / bidirectional / sliding-window masks, q-chunked
attention (bounded memory for long prefill), and single-step decode against a
KV cache. All softmax arithmetic in float32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(q_pos, k_pos, causal: bool, window: int):
    """q_pos (..., Tq), k_pos (..., Tk) -> bool (..., Tq, Tk). window may be a
    traced scalar (0 = unlimited)."""
    d = q_pos[..., :, None] - k_pos[..., None, :]
    m = (d >= 0) if causal else jnp.ones(d.shape, bool)
    w = jnp.asarray(window)
    m = jnp.where(w > 0, m & (d < w), m)
    return m


def _attend(q, k, v, mask):
    """q (B,Tq,K,G,h), k/v (B,Tk,K,h), mask (B?,Tq,Tk) -> (B,Tq,K,G,h).

    Softmax statistics in f32; the normalized probs are cast back to the
    model dtype before the PV matmul (halves the dominant (T,S) HBM term and
    uses the bf16 MXU path — standard flash-attention practice)."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    logits = jnp.einsum("btkgh,bskh->bkgts", q, k,
                        preferred_element_type=jnp.float32) * scale
    if mask.ndim == 2:
        mask = mask[None]
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskh->btkgh", probs, v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype)


def multihead_attention(q, k, v, *, causal=True, window=0, chunk=0):
    """q (B,Tq,H,h), k/v (B,Tk,K,h) with H = K*G (GQA). -> (B,Tq,H,h)."""
    B, T, H, h = q.shape
    Tk, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, T, K, G, h)
    q_pos = jnp.arange(T)
    k_pos = jnp.arange(Tk)

    if chunk and T % chunk == 0 and T > chunk:
        nc = T // chunk

        def one(qc_and_pos):
            qc, qp = qc_and_pos  # (B,chunk,K,G,h), (chunk,)
            m = _mask(qp, k_pos, causal, window)
            return _attend(qc, k, v, m)

        qcs = jnp.moveaxis(qg.reshape(B, nc, chunk, K, G, h), 1, 0)
        out = jax.lax.map(one, (qcs, q_pos.reshape(nc, chunk)))
        out = jnp.moveaxis(out, 0, 1).reshape(B, T, H, h)
        return out

    m = _mask(q_pos, k_pos, causal, window)
    return _attend(qg, k, v, m).reshape(B, T, H, h)


def banded_attention(q, k, v, *, window: int, chunk: int = 0):
    """Causal sliding-window attention with a STATIC window: each query chunk
    only reads the (window + chunk)-wide key band — O(T * window) compute and
    memory instead of O(T^2)-then-mask. q (B,T,H,h), k/v (B,T,K,h)."""
    B, T, H, h = q.shape
    K = k.shape[2]
    G = H // K
    chunk = chunk or min(T, max(128, window // 2))
    if T % chunk or T <= chunk:
        qg = q.reshape(B, T, K, G, h)
        m = _mask(jnp.arange(T), jnp.arange(T), True, window)
        return _attend(qg, k, v, m).reshape(B, T, H, h)
    nc = T // chunk
    band = window + chunk
    qg = q.reshape(B, nc, chunk, K, G, h)

    def one(args):
        qc, ci = args                                   # (B,chunk,K,G,h), ()
        start = jnp.maximum(0, (ci + 1) * chunk - band)
        kb = jax.lax.dynamic_slice_in_dim(k, start, min(band, T), axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, min(band, T), axis=1)
        q_pos = ci * chunk + jnp.arange(chunk)
        k_pos = start + jnp.arange(kb.shape[1])
        m = _mask(q_pos, k_pos, True, window)
        return _attend(qc, kb, vb, m)

    out = jax.lax.map(one, (jnp.moveaxis(qg, 1, 0), jnp.arange(nc)))
    return jnp.moveaxis(out, 0, 1).reshape(B, T, H, h)


def decode_attention(q, k_cache, v_cache, pos, *, window=0):
    """One-step decode. q (B,1,H,h); caches (B,S,K,h); pos scalar index of the
    current token (cache[pos] is the current token's kv). -> (B,1,H,h)."""
    B, _, H, h = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, 1, K, G, h)
    k_pos = jnp.arange(S)
    valid = k_pos <= pos
    w = jnp.asarray(window)
    valid = jnp.where(w > 0, valid & (pos - k_pos < w), valid)
    m = valid[None, None, :]  # (1,1,S) -> broadcast (B,Tq=1,S)
    return _attend(qg, k_cache, v_cache, jnp.broadcast_to(m, (B, 1, S))).reshape(B, 1, H, h)


def update_cache(cache_k, cache_v, k_new, v_new, pos):
    """Write k/v (B,1,K,h) at index pos. Returns updated caches."""
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new.astype(cache_k.dtype), pos, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new.astype(cache_v.dtype), pos, axis=1)
    return ck, cv
