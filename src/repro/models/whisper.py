"""Whisper-style encoder-decoder (arXiv:2212.04356).

The conv audio frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, Tf, frame_dim); a tapped linear projects
them into the encoder. Encoder = bidirectional pre-LN blocks with sinusoidal
positions; decoder = causal self-attention + cross-attention with learned
positional embeddings. LayerNorm + GELU throughout (Whisper convention).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.tape import Tape, fix_scan_params, subtape_run
from repro.models import layers as L
from repro.models.attention import (decode_attention, multihead_attention,
                                    update_cache)
from repro.models.transformer import attn_init, _qkv, mlp_init, mlp_apply


def _sinusoid(T, d):
    pos = jnp.arange(T, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# -------------------------------------------------------------------- blocks
def enc_block_init(rng, cfg):
    ks = jax.random.split(rng, 3)
    dt = jnp.dtype(cfg.param_dtype)
    return {"ln1": L.layernorm_init(ks[0], cfg.d_model, dt),
            "attn": attn_init(ks[1], cfg),
            "ln2": L.layernorm_init(ks[0], cfg.d_model, dt),
            "mlp": mlp_init(ks[2], cfg)}


def enc_block_apply(p, tape, x, cfg):
    with tape.scope("attn"):
        xn = L.layernorm(p["ln1"], x)
        q, k, v = _qkv(p["attn"], tape, xn, cfg, None, None)
        if cfg.seq_shard_attn:
            import jax
            from jax.sharding import PartitionSpec as P
            q = jax.lax.with_sharding_constraint(q, P(None, "model", None, None))
            a = multihead_attention(q, k, v, causal=False)
            a = jax.lax.with_sharding_constraint(a, P(None, "model", None, None))
        else:
            a = multihead_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
        x = x + L.linear(tape, "o", p["attn"]["o"],
                         a.reshape(x.shape[0], x.shape[1], -1))
    with tape.scope("mlp"):
        x = x + mlp_apply(p["mlp"], tape, L.layernorm(p["ln2"], x), cfg)
    return x


def dec_block_init(rng, cfg):
    ks = jax.random.split(rng, 5)
    dt = jnp.dtype(cfg.param_dtype)
    d, H, h = cfg.d_model, cfg.n_heads, cfg.hd
    xattn = {"q": L.linear_init(ks[0], d, H * h, dt),
             "kv": L.linear_init(ks[1], d, 2 * H * h, dt),
             "o": L.linear_init(ks[2], H * h, d, dt)}
    return {"ln1": L.layernorm_init(ks[0], d, dt),
            "attn": attn_init(ks[3], cfg),
            "lnx": L.layernorm_init(ks[0], d, dt),
            "xattn": xattn,
            "ln2": L.layernorm_init(ks[0], d, dt),
            "mlp": mlp_init(ks[4], cfg)}


# ------------------------------------------------------------------------ LM
class WhisperLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, rng):
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        ks = jax.random.split(rng, 8)
        n_enc = cfg.encoder_layers or cfg.n_layers
        return {
            "frontend": L.linear_init(ks[0], cfg.frame_dim or cfg.d_model,
                                      cfg.d_model, dt, bias=True),
            "enc_blocks": jax.vmap(lambda k: enc_block_init(k, cfg))(
                jax.random.split(ks[1], n_enc)),
            "enc_norm": L.layernorm_init(ks[0], cfg.d_model, dt),
            "embed": L.embedding_init(ks[2], cfg.vocab, cfg.d_model, dt),
            "pos": {"e": L.normal_init(ks[3], (cfg.decoder_len, cfg.d_model),
                                       dt, 0.01)},
            "dec_blocks": jax.vmap(lambda k: dec_block_init(k, cfg))(
                jax.random.split(ks[4], cfg.n_layers)),
            "final_norm": L.layernorm_init(ks[0], cfg.d_model, dt),
            "head": L.linear_init(ks[5], cfg.d_model, cfg.vocab, dt),
        }

    # ---------------------------------------------------------------- encode
    def encode(self, params, tape, frames):
        cfg = self.cfg
        x = L.linear(tape, "frontend", params["frontend"],
                     frames.astype(jnp.dtype(cfg.dtype)))
        x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)[None]
        sub = tape.subtaps("enc_blocks")
        tapped = sub is not None

        def body(xx, xs):
            p_l, taps_l = xs
            out, aux = subtape_run(
                lambda pp, tp: enc_block_apply(pp, tp, xx, cfg),
                p_l, taps_l if tapped else None, collect=tape.collect)
            return out, aux

        blocks = fix_scan_params(params["enc_blocks"], tapped)
        x, (acts, tapz) = jax.lax.scan(body, x, (blocks,
                                                 sub if tapped else {}))
        tape.merge_stacked("enc_blocks", acts, tapz)
        return L.layernorm(params["enc_norm"], x)

    # ---------------------------------------------------------------- decode
    def _dec_embed(self, params, tape, tokens, pos0=0):
        x = L.embedding(tape, "embed", params["embed"], tokens)
        pe = params["pos"]["e"]
        if pe.ndim == 3:  # psp (B, decoder_len, d)
            pos = jax.lax.dynamic_slice_in_dim(pe, pos0, tokens.shape[1], axis=1)
        else:
            pos = jax.lax.dynamic_slice_in_dim(pe, pos0, tokens.shape[1], axis=0)[None]
        return x + pos.astype(x.dtype)

    def _dec_blocks(self, params, tape, x, enc):
        cfg = self.cfg
        H, h = cfg.n_heads, cfg.hd
        sub = tape.subtaps("dec_blocks")
        tapped = sub is not None

        def body(xx, xs):
            p_l, taps_l = xs

            def run(pp, tp):
                B, Tf = enc.shape[0], enc.shape[1]
                kv = L.linear(tp, "xattn/kv", pp["xattn"]["kv"], enc)
                k, v = jnp.split(kv, 2, axis=-1)
                return dec_block_apply_pre(pp, tp, xx,
                                           k.reshape(B, Tf, H, h),
                                           v.reshape(B, Tf, H, h), cfg)

            out, aux = subtape_run(run, p_l, taps_l if tapped else None,
                                   collect=tape.collect)
            return out, aux

        blocks = fix_scan_params(params["dec_blocks"], tapped)
        x, (acts, tapz) = jax.lax.scan(body, x, (blocks,
                                                 sub if tapped else {}))
        tape.merge_stacked("dec_blocks", acts, tapz)
        return x

    # ------------------------------------------------------------------ train
    def apply(self, params, batch, tape: Tape):
        """batch {'frames': (B,Tf,frame_dim), 'tokens': (B,Td)} -> (B,)."""
        cfg = self.cfg
        enc = self.encode(params, tape, batch["frames"])
        tokens = batch["tokens"]
        x = self._dec_embed(params, tape, tokens)
        x = self._dec_blocks(params, tape, x, enc)
        x = L.layernorm(params["final_norm"], x)
        logits = L.linear(tape, "head", params["head"], x)
        mask = batch.get("mask")
        mask = mask[:, 1:] if mask is not None else None
        return L.lm_per_sample_loss(logits[:, :-1], tokens[:, 1:], mask)

    # ---------------------------------------------------------------- serving
    def prefill(self, params, frames, tokens):
        """Encode frames + full decoder -> last-position logits (B,V)."""
        tape = Tape.null()
        enc = self.encode(params, tape, frames)
        x = self._dec_embed(params, tape, tokens)
        x = self._dec_blocks(params, tape, x, enc)
        x = L.layernorm(params["final_norm"], x)
        return L.linear(tape, "head", params["head"], x[:, -1:, :])[:, 0]

    def init_cache(self, B, S, Tf=0, dtype=None):
        cfg = self.cfg
        dt = jnp.dtype(dtype or cfg.dtype)
        Lc, H, h = cfg.n_layers, cfg.n_heads, cfg.hd
        Tf = Tf or S
        return {"k": jnp.zeros((Lc, B, cfg.decoder_len, H, h), dt),
                "v": jnp.zeros((Lc, B, cfg.decoder_len, H, h), dt),
                "xk": jnp.zeros((Lc, B, Tf, H, h), dt),
                "xv": jnp.zeros((Lc, B, Tf, H, h), dt)}

    def prefill_cross(self, params, frames, cache):
        """Encode audio once; fill the cross-attention KV cache."""
        cfg = self.cfg
        tape = Tape.null()
        enc = self.encode(params, tape, frames)
        B, Tf = enc.shape[0], enc.shape[1]
        H, h = cfg.n_heads, cfg.hd

        def body(_, p_l):
            kv = L.linear(tape, "xattn/kv", p_l["xattn"]["kv"], enc)
            k, v = jnp.split(kv, 2, axis=-1)
            return _, (k.reshape(B, Tf, H, h), v.reshape(B, Tf, H, h))

        _, (xk, xv) = jax.lax.scan(body, None, params["dec_blocks"])
        return dict(cache, xk=xk.astype(cache["xk"].dtype),
                    xv=xv.astype(cache["xv"].dtype))

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        tape = Tape.null()
        x = self._dec_embed(params, tape, tokens[:, None], pos0=pos)

        def body(xx, xs):
            p_l, ck, cv, xk, xv = xs
            with tape.scope("self"):
                xn = L.layernorm(p_l["ln1"], xx)
                q, k, v = _qkv(p_l["attn"], tape, xn, cfg, None, None)
                ck, cv = update_cache(ck, cv, k, v, pos)
                a = decode_attention(q, ck, cv, pos)
                xx = xx + L.linear(tape, "o", p_l["attn"]["o"],
                                   a.reshape(a.shape[0], 1, -1))
            with tape.scope("cross"):
                xx = xx + cross_attn_decode(p_l["xattn"], tape,
                                            L.layernorm(p_l["lnx"], xx),
                                            xk, xv, cfg)
            with tape.scope("mlp"):
                xx = xx + mlp_apply(p_l["mlp"], tape,
                                    L.layernorm(p_l["ln2"], xx), cfg)
            return xx, (ck, cv)

        x, (nk, nv) = jax.lax.scan(body, x, (params["dec_blocks"], cache["k"],
                                             cache["v"], cache["xk"],
                                             cache["xv"]))
        x = L.layernorm(params["final_norm"], x)
        logits = L.linear(tape, "head", params["head"], x)
        return logits[:, 0, :], dict(cache, k=nk, v=nv)


def dec_block_apply_pre(p, tape, x, enc_k, enc_v, cfg):
    """Decoder block with precomputed cross K/V (used under scan where the
    per-layer cross projections are computed inside the body)."""
    with tape.scope("attn"):
        xn = L.layernorm(p["ln1"], x)
        q, k, v = _qkv(p["attn"], tape, xn, cfg, None, None)
        a = multihead_attention(q, k, v, causal=True)
        x = x + L.linear(tape, "o", p["attn"]["o"],
                         a.reshape(x.shape[0], x.shape[1], -1))
    with tape.scope("xattn"):
        xn = L.layernorm(p["lnx"], x)
        B, Td = xn.shape[0], xn.shape[1]
        H, h = cfg.n_heads, cfg.hd
        q = L.linear(tape, "q", p["xattn"]["q"], xn).reshape(B, Td, H, h)
        out = multihead_attention(q, enc_k, enc_v, causal=False)
        x = x + L.linear(tape, "o", p["xattn"]["o"], out.reshape(B, Td, -1))
    with tape.scope("mlp"):
        x = x + mlp_apply(p["mlp"], tape, L.layernorm(p["ln2"], x), cfg)
    return x


def cross_attn_decode(p, tape, x, enc_k, enc_v, cfg):
    B = x.shape[0]
    H, h = cfg.n_heads, cfg.hd
    q = L.linear(tape, "q", p["q"], x).reshape(B, 1, H, h)
    out = multihead_attention(q, enc_k, enc_v, causal=False)
    return L.linear(tape, "o", p["o"], out.reshape(B, 1, -1))
