"""Mixture-of-Experts with per-(sample, expert) capacity dispatch.

DP correctness note: classic GShard-style dispatch shares expert capacity
across the whole token batch, which makes one sample's gradient depend on
*other* samples' routing (capacity overflow drops) — that breaks the
per-sample sensitivity analysis DP-SGD relies on. Here capacity is allocated
per (sample, expert): routing, drops and therefore per-sample gradients are
functions of the sample alone. This also makes the per-(b,e) token groups the
natural ghost-norm unit (Gram over each sample's routed tokens) — the
beyond-paper MoE extension of the BK algorithm (DESIGN.md §6).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def capacity(cfg: ModelConfig, T: int) -> int:
    cap = int(math.ceil(cfg.capacity_factor * cfg.top_k * T / cfg.n_experts))
    return max(1, min(cap, T))


def moe_init(rng, cfg: ModelConfig):
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 4)
    mult = 2 if cfg.act == "swiglu" else 1
    p = {
        "router": L.linear_init(ks[0], d, E, dt),
        "experts": {
            "up": {"w": L.normal_init(ks[1], (E, d, mult * ff), dt,
                                      1.0 / math.sqrt(d))},
            "down": {"w": L.normal_init(ks[2], (E, ff, d), dt,
                                        1.0 / math.sqrt(ff))},
        },
    }
    if cfg.n_shared:
        from repro.models.transformer import mlp_init  # local to avoid cycle
        p["shared"] = mlp_init(ks[3], cfg, d_ff=cfg.n_shared * ff)
    return p


def moe_linear(tape, name, p, xg, valid, act_in):
    """Tapped expert matmul: xg (B,E,C,din) @ w (E,din,dout).

    The tap record keeps (activation, slot-validity mask) — the unit of the
    per-(sample, expert) ghost norm.
    """
    s = jnp.einsum("becd,edf->becf", xg, p["w"])
    return tape.record(name, "moe", s, {"a": act_in, "mask": valid})


def moe_apply(p, tape, x, cfg: ModelConfig):
    """x (B,T,d) -> (B,T,d)."""
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    cap = capacity(cfg, T)

    logits = L.linear(tape, "router", p["router"], x).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                       # (B,T,E)
    topv, topi = jax.lax.top_k(probs, k)                          # (B,T,k)
    if cfg.renorm_topk:
        topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    sel = jnp.sum(jax.nn.one_hot(topi, E, dtype=jnp.float32), axis=2)  # (B,T,E)
    weight = jnp.einsum("btk,btke->bte", topv,
                        jax.nn.one_hot(topi, E, dtype=jnp.float32))

    # --- per-(b,e) slot assignment --------------------------------------
    pos = jnp.cumsum(sel, axis=1) - 1.0                           # (B,T,E)
    pos = pos.astype(jnp.int32)
    keep = (sel > 0) & (pos < cap)
    b_ix = jnp.arange(B)[:, None, None]
    e_ix = jnp.arange(E)[None, None, :]
    t_ix = jnp.broadcast_to(jnp.arange(T)[None, :, None], (B, T, E))
    slot_pos = jnp.where(keep, pos, cap)                          # cap -> dropped
    slot_t = jnp.zeros((B, E, cap), jnp.int32).at[
        b_ix, e_ix, slot_pos].set(t_ix, mode="drop")
    valid = jnp.zeros((B, E, cap), jnp.float32).at[
        b_ix, e_ix, slot_pos].set(1.0, mode="drop")

    xg = x[jnp.arange(B)[:, None, None], slot_t]                  # (B,E,C,d)
    xg = xg * valid[..., None].astype(xg.dtype)

    # --- expert FFN (tapped) ---------------------------------------------
    with tape.scope("experts"):
        ep = p["experts"]
        u = moe_linear(tape, "up", ep["up"], xg, valid, xg)
        if cfg.act == "swiglu":
            g, u = jnp.split(u, 2, axis=-1)
            h = jax.nn.silu(g) * u
        else:
            h = jax.nn.gelu(u)
        h = h * valid[..., None].astype(h.dtype)
        out = moe_linear(tape, "down", ep["down"], h, valid, h)
        out = out * valid[..., None].astype(out.dtype)

    # --- combine ----------------------------------------------------------
    g_slot = jnp.clip(pos, 0, cap - 1)                            # (B,T,E)
    per_e = out[b_ix, e_ix, g_slot]                               # (B,T,E,d)
    w_eff = (weight * keep.astype(weight.dtype)).astype(per_e.dtype)
    y = jnp.einsum("bted,bte->btd", per_e, w_eff)

    if cfg.n_shared:
        from repro.models.transformer import mlp_apply
        with tape.scope("shared"):
            y = y + mlp_apply(p["shared"], tape, x, cfg)
    return y.astype(x.dtype)
