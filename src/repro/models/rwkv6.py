"""RWKV6 "Finch" (arXiv:2404.05892) — attention-free LM with data-dependent
per-channel decay. Time-mix recurrence:

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

with w_t = exp(-exp(w0 + tanh(x_w A) B)) (decay LoRA) and dynamic token-shift
mixing (5-way lerp deltas through a small tanh bottleneck). All projections
and LoRA matmuls are tapped generalized-linear ops; per-channel vectors
(maa_*, w0, u, norm scales) take the psp route. Decode is O(1) state.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.tape import Tape, fix_scan_params, subtape_run
from repro.models import layers as L

TM_DIM = 32       # token-shift bottleneck (TIME_MIX_EXTRA_DIM)
DECAY_DIM = 64    # decay LoRA rank (TIME_DECAY_EXTRA_DIM)
HEAD_DIM = 64


def _shift(x):
    """Previous-token shift along T, zeros at t=0."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def wkv6_ref(r, k, v, w, u):
    """Reference recurrence. r,k,v,w (B,T,H,h); u (H,h) or (B,H,h) -> (B,T,H,h)."""
    B, T, H, h = r.shape
    f32 = jnp.float32
    r, k, v, w = (t.astype(f32) for t in (r, k, v, w))
    u = u.astype(f32)
    u_b = u if u.ndim == 3 else jnp.broadcast_to(u, (B, H, h))

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                      # (B,H,h)
        kv = k_t[..., :, None] * v_t[..., None, :]    # (B,H,h,h)
        out = (jnp.einsum("bhi,bhij->bhj", r_t, S)
               + jnp.sum(r_t * u_b * k_t, -1, keepdims=True) * v_t)
        S = w_t[..., :, None] * S + kv
        return S, out

    S0 = jnp.zeros((B, H, h, h), f32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    _, out = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(out, 0, 1)


def wkv6_chunked(r, k, v, w, u, chunk: int = 32):
    """Chunked recurrence (same math as kernels/wkv6): intra-chunk matmul
    form + inter-chunk state scan. For training/prefill at long T this cuts
    the backward-saved scan carries from T to T/chunk states."""
    B, T, H, h = r.shape
    f32 = jnp.float32
    pad = (chunk - T % chunk) % chunk
    if pad:
        zp = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    Tp = r.shape[1]
    nc = Tp // chunk
    u_b = u.astype(f32)
    if u_b.ndim == 2:
        u_b = jnp.broadcast_to(u_b, (B, H, h))
    # (nc, B, H, c, h) chunks
    ch = lambda x: jnp.moveaxis(
        x.astype(f32).reshape(B, nc, chunk, H, h), (1, 3), (0, 2))
    rc, kc, vc, wc = ch(r), ch(k), ch(v), ch(w)
    strict = jnp.tril(jnp.ones((chunk, chunk), f32), -1)

    def step(S, inp):
        rb, kb, vb, wb = inp                         # (B,H,c,h)
        logw = jnp.log(jnp.maximum(wb, 1e-30))
        cum = jnp.cumsum(logw, axis=2)               # inclusive
        P = jnp.exp(cum)
        P_prev = jnp.exp(cum - logw)
        rt = rb * P_prev
        kt = kb / jnp.maximum(P, 1e-30)
        A = jnp.einsum("bhik,bhjk->bhij", rt, kt) * strict
        diag = jnp.einsum("bhik,bhik->bhi", rb * u_b[:, :, None, :], kb)
        out = (jnp.einsum("bhij,bhjk->bhik", A, vb)
               + diag[..., None] * vb
               + jnp.einsum("bhik,bhkj->bhij", rt, S))
        Pc = P[:, :, -1]                             # (B,H,h)
        S = (Pc[..., None] * S
             + jnp.einsum("bhik,bhij->bhkj", kt * Pc[:, :, None, :], vb))
        return S, out

    S0 = jnp.zeros((B, H, h, h), f32)
    _, out = jax.lax.scan(step, S0, (rc, kc, vc, wc))
    out = jnp.moveaxis(out, (0, 2), (1, 3)).reshape(B, Tp, H, h)
    return out[:, :T]


def wkv6_step(S, r, k, v, w, u):
    """Single decode step. r,k,v,w (B,H,h); S (B,H,h,h)."""
    f32 = jnp.float32
    r, k, v, w, S = (t.astype(f32) for t in (r, k, v, w, S))
    u_b = u.astype(f32)
    kv = k[..., :, None] * v[..., None, :]
    out = (jnp.einsum("bhi,bhij->bhj", r, S)
           + jnp.sum(r * u_b * k, -1, keepdims=True) * v)
    return w[..., :, None] * S + kv, out


# -------------------------------------------------------------------- block
def block_init(rng, cfg: ModelConfig):
    d, ff = cfg.d_model, cfg.d_ff
    H = d // HEAD_DIM
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, 16)
    lin = lambda i, a, b, s=None: L.linear_init(ks[i], a, b, dt, scale=s)
    vec = lambda shape, val=0.0: jnp.full(shape, val, dt)
    att = {
        "maa_x": vec((d,)), "maa_w": vec((d,)), "maa_k": vec((d,)),
        "maa_v": vec((d,)), "maa_r": vec((d,)), "maa_g": vec((d,)),
        "tm_w1": lin(0, d, 5 * TM_DIM, 0.01),
        **{f"tm_w2_{i}": lin(1 + i, TM_DIM, d, 0.01) for i in range(5)},
        "w0": vec((d,), -5.0),
        "wa": lin(6, d, DECAY_DIM, 0.01), "wb": lin(7, DECAY_DIM, d, 0.01),
        "r": lin(8, d, d), "k": lin(9, d, d), "v": lin(10, d, d),
        "g": lin(11, d, d), "o": lin(12, d, d),
        "u": vec((H, HEAD_DIM), 0.5),
        "lnx_g": jnp.ones((d,), dt), "lnx_b": vec((d,)),
    }
    ffn = {
        "maa_fk": vec((d,)), "maa_fr": vec((d,)),
        "key": lin(13, d, ff), "value": lin(14, ff, d),
        "receptance": lin(15, d, d),
    }
    return {"ln1": L.layernorm_init(None, d, dt), "att": att,
            "ln2": L.layernorm_init(None, d, dt), "ffn": ffn}


def _group_norm(xf, g, b, H, eps=64e-5):
    B, T, d = xf.shape
    xh = xf.reshape(B, T, H, -1).astype(jnp.float32)
    mu = jnp.mean(xh, -1, keepdims=True)
    var = jnp.var(xh, -1, keepdims=True)
    nrm = ((xh - mu) * jax.lax.rsqrt(var + eps)).reshape(B, T, d)
    return (nrm * L.align(g, nrm).astype(jnp.float32)
            + L.align(b, nrm).astype(jnp.float32)).astype(xf.dtype)


def _mix(xn, sx, maa, delta=None):
    m = L.align(maa, xn)
    if delta is not None:
        m = m + delta
    return xn + sx * m


def _time_mix_inputs(p, tape, xn, sx):
    """Dynamic 5-way token-shift mixing -> (xw, xk, xv, xr, xg)."""
    xxx = _mix(xn, sx, p["maa_x"])
    z = jnp.tanh(L.linear(tape, "tm_w1", p["tm_w1"], xxx))
    zs = jnp.split(z, 5, axis=-1)
    deltas = [L.linear(tape, f"tm_w2_{i}", p[f"tm_w2_{i}"], zs[i])
              for i in range(5)]
    names = ["maa_w", "maa_k", "maa_v", "maa_r", "maa_g"]
    return tuple(_mix(xn, sx, p[n], dlt) for n, dlt in zip(names, deltas))


def _decay(p, tape, xw):
    ww = L.linear(tape, "wb", p["wb"],
                  jnp.tanh(L.linear(tape, "wa", p["wa"], xw)))
    logw = L.align(p["w0"], ww).astype(jnp.float32) + ww.astype(jnp.float32)
    return jnp.exp(-jnp.exp(logw))


def _att_proj(p, tape, xn, sx):
    xw, xk, xv, xr, xg = _time_mix_inputs(p, tape, xn, sx)
    r = L.linear(tape, "r", p["r"], xr)
    k = L.linear(tape, "k", p["k"], xk)
    v = L.linear(tape, "v", p["v"], xv)
    g = jax.nn.silu(L.linear(tape, "g", p["g"], xg))
    w = _decay(p, tape, xw)
    return r, k, v, g, w


def _heads(t, H):
    B, T, d = t.shape
    return t.reshape(B, T, H, HEAD_DIM)


def block_apply(p, tape, x, cfg: ModelConfig):
    d = cfg.d_model
    H = d // HEAD_DIM
    # --- time mix ---------------------------------------------------------
    xn = L.layernorm(p["ln1"], x)
    sx = _shift(xn) - xn
    with tape.scope("att"):
        r, k, v, g, w = _att_proj(p["att"], tape, xn, sx)
        u = p["att"]["u"]
        if x.shape[1] >= 2 * cfg.ssm_chunk:
            wkv = wkv6_chunked(_heads(r, H), _heads(k, H), _heads(v, H),
                               _heads(w.astype(x.dtype), H), u,
                               chunk=cfg.ssm_chunk)
        else:
            wkv = wkv6_ref(_heads(r, H), _heads(k, H), _heads(v, H),
                           _heads(w.astype(x.dtype), H), u)
        out = _group_norm(wkv.reshape(x.shape).astype(x.dtype),
                          p["att"]["lnx_g"], p["att"]["lnx_b"], H)
        x = x + L.linear(tape, "o", p["att"]["o"], out * g)
    # --- channel mix --------------------------------------------------------
    xn2 = L.layernorm(p["ln2"], x)
    sx2 = _shift(xn2) - xn2
    with tape.scope("ffn"):
        fp = p["ffn"]
        xk2 = _mix(xn2, sx2, fp["maa_fk"])
        xr2 = _mix(xn2, sx2, fp["maa_fr"])
        kk = jnp.square(jax.nn.relu(L.linear(tape, "key", fp["key"], xk2)))
        rr = jax.nn.sigmoid(L.linear(tape, "receptance", fp["receptance"], xr2))
        x = x + rr * L.linear(tape, "value", fp["value"], kk)
    return x


def block_decode(p, tape, x, cache, cfg: ModelConfig):
    """x (B,1,d); cache {'S': (B,H,h,h), 'att_sx': (B,d), 'ffn_sx': (B,d)}."""
    d = cfg.d_model
    H = d // HEAD_DIM
    xn = L.layernorm(p["ln1"], x)
    sx = cache["att_sx"][:, None, :].astype(xn.dtype) - xn
    with tape.scope("att"):
        r, k, v, g, w = _att_proj(p["att"], tape, xn, sx)
        u = p["att"]["u"]
        S, out1 = wkv6_step(cache["S"], _heads(r, H)[:, 0], _heads(k, H)[:, 0],
                            _heads(v, H)[:, 0],
                            _heads(w.astype(x.dtype), H)[:, 0], u)
        out = _group_norm(out1[:, None].reshape(x.shape).astype(x.dtype),
                          p["att"]["lnx_g"], p["att"]["lnx_b"], H)
        x = x + L.linear(tape, "o", p["att"]["o"], out * g)
    xn2 = L.layernorm(p["ln2"], x)
    sx2 = cache["ffn_sx"][:, None, :].astype(xn2.dtype) - xn2
    with tape.scope("ffn"):
        fp = p["ffn"]
        xk2 = _mix(xn2, sx2, fp["maa_fk"])
        xr2 = _mix(xn2, sx2, fp["maa_fr"])
        kk = jnp.square(jax.nn.relu(L.linear(tape, "key", fp["key"], xk2)))
        rr = jax.nn.sigmoid(L.linear(tape, "receptance", fp["receptance"], xr2))
        x = x + rr * L.linear(tape, "value", fp["value"], kk)
    new_cache = {"S": S.astype(cache["S"].dtype), "att_sx": xn[:, 0],
                 "ffn_sx": xn2[:, 0]}
    return x, new_cache


# ----------------------------------------------------------------------- LM
class Rwkv6LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, rng):
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        ks = jax.random.split(rng, 4)
        blocks = jax.vmap(lambda k: block_init(k, cfg))(
            jax.random.split(ks[0], cfg.n_layers))
        return {"embed": L.embedding_init(ks[1], cfg.vocab, cfg.d_model, dt),
                "ln_in": L.layernorm_init(None, cfg.d_model, dt),
                "blocks": blocks,
                "final_norm": L.layernorm_init(None, cfg.d_model, dt),
                "head": L.linear_init(ks[2], cfg.d_model, cfg.vocab, dt)}

    def _scan_blocks(self, params, tape, x):
        cfg = self.cfg
        sub = tape.subtaps("blocks")
        tapped = sub is not None

        def block(p_l, t_l, xx):
            return subtape_run(lambda pp, tp: block_apply(pp, tp, xx, cfg),
                               p_l, t_l, collect=tape.collect)

        run = jax.checkpoint(block) if cfg.remat else block

        def body(xx, xs):
            p_l, taps_l = xs
            out, aux = run(p_l, taps_l if tapped else None, xx)
            return out, aux

        blocks = fix_scan_params(params["blocks"], tapped)
        x, (acts, tapz) = jax.lax.scan(body, x, (blocks,
                                                 sub if tapped else {}))
        tape.merge_stacked("blocks", acts, tapz)
        return x

    def apply(self, params, batch, tape: Tape):
        tokens = batch["tokens"]
        x = L.embedding(tape, "embed", params["embed"], tokens)
        x = L.layernorm(params["ln_in"], x)
        x = self._scan_blocks(params, tape, x)
        x = L.layernorm(params["final_norm"], x)
        logits = L.linear(tape, "head", params["head"], x)
        mask = batch.get("mask")
        mask = mask[:, 1:] if mask is not None else None
        return L.lm_per_sample_loss(logits[:, :-1], tokens[:, 1:], mask)

    def prefill(self, params, tokens):
        """Serving prefill -> last-position logits (B,V)."""
        tape = Tape.null()
        x = L.embedding(tape, "embed", params["embed"], tokens)
        x = L.layernorm(params["ln_in"], x)
        x = self._scan_blocks(params, tape, x)
        x = L.layernorm(params["final_norm"], x)
        return L.linear(tape, "head", params["head"], x[:, -1:, :])[:, 0]

    def init_cache(self, B, S=0, dtype=None):
        cfg = self.cfg
        dt = jnp.dtype(dtype or cfg.dtype)
        H = cfg.d_model // HEAD_DIM
        Lc = cfg.n_layers
        return {"S": jnp.zeros((Lc, B, H, HEAD_DIM, HEAD_DIM), jnp.float32),
                "att_sx": jnp.zeros((Lc, B, cfg.d_model), dt),
                "ffn_sx": jnp.zeros((Lc, B, cfg.d_model), dt)}

    def decode_step(self, params, cache, tokens, pos=None):
        cfg = self.cfg
        tape = Tape.null()
        x = L.embedding(tape, "embed", params["embed"], tokens[:, None])
        x = L.layernorm(params["ln_in"], x)

        def body(xx, xs):
            p_l, c_l = xs
            out, c_l = block_decode(p_l, tape, xx, c_l, cfg)
            return out, c_l

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        x = L.layernorm(params["final_norm"], x)
        logits = L.linear(tape, "head", params["head"], x)
        return logits[:, 0, :], new_cache
