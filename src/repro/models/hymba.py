"""Hymba (arXiv:2411.13676) — hybrid-head blocks running attention and a
Mamba-style SSM **in parallel** on the same input, outputs mean-fused after
per-branch normalization, plus learnable meta tokens prepended to the
sequence.

Layer layout follows the paper: sliding-window attention everywhere except
three GLOBAL attention layers (first / middle / last). The SWA layers are
lax.scan'd in two segments around the middle global layer, which keeps the
window STATIC so SWA uses banded attention (O(T*window), never T^2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.tape import Tape, fix_scan_params, subtape_run
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.attention import (banded_attention, decode_attention,
                                    multihead_attention, update_cache)
from repro.models.transformer import attn_init, _qkv, mlp_init, mlp_apply


def block_init(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 6)
    d = cfg.d_model
    d_inner = cfg.ssm_heads * cfg.hd
    dt = jnp.dtype(cfg.param_dtype)
    attn = attn_init(ks[1], cfg)
    del attn["o"]  # fused output projection (fuse_o) replaces per-branch o
    return {
        "ln1": L.rmsnorm_init(ks[0], d, dt),
        "attn": attn,
        "ssm": S.ssm_init(ks[2], cfg),
        "na": L.rmsnorm_init(ks[0], d_inner, dt),
        "ns": L.rmsnorm_init(ks[0], d_inner, dt),
        "fuse_o": L.linear_init(ks[3], d_inner, d, dt),
        "ln2": L.rmsnorm_init(ks[0], d, dt),
        "mlp": mlp_init(ks[4], cfg),
    }


def block_apply(p, tape, x, cfg: ModelConfig, cos, sin, window: int):
    """window: STATIC int (0 = global attention for this layer)."""
    B, T = x.shape[0], x.shape[1]
    xn = L.rmsnorm(p["ln1"], x)
    with tape.scope("attn"):
        q, k, v = _qkv(p["attn"], tape, xn, cfg, cos, sin)
        if cfg.seq_shard_attn and not window:
            from jax.sharding import PartitionSpec as P
            q = jax.lax.with_sharding_constraint(q, P(None, "model", None, None))
            a = multihead_attention(q, k, v, causal=True)
            a = jax.lax.with_sharding_constraint(a, P(None, "model", None, None))
        elif window:
            a = banded_attention(q, k, v, window=window, chunk=cfg.attn_chunk)
        else:
            a = multihead_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
        a = a.reshape(B, T, -1)
    with tape.scope("ssm"):
        s = S.ssm_apply(p["ssm"], tape, xn, cfg)
    fused = 0.5 * (L.rmsnorm(p["na"], a) + L.rmsnorm(p["ns"], s))
    x = x + L.linear(tape, "fuse_o", p["fuse_o"], fused)
    with tape.scope("mlp"):
        x = x + mlp_apply(p["mlp"], tape, L.rmsnorm(p["ln2"], x), cfg)
    return x


def block_decode(p, tape, x, cache, pos, cfg: ModelConfig, cos, sin, window):
    B = x.shape[0]
    xn = L.rmsnorm(p["ln1"], x)
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _qkv(p["attn"], tape, xn, cfg, cos, sin, positions)
    ck, cv = update_cache(cache["k"], cache["v"], k, v, pos)
    a = decode_attention(q, ck, cv, pos, window=window).reshape(B, 1, -1)
    s, h = S.ssm_decode(p["ssm"], tape, xn, cache["h"], cfg)
    fused = 0.5 * (L.rmsnorm(p["na"], a) + L.rmsnorm(p["ns"], s))
    x = x + L.linear(tape, "fuse_o", p["fuse_o"], fused)
    x = x + mlp_apply(p["mlp"], tape, L.rmsnorm(p["ln2"], x), cfg)
    return x, {"k": ck, "v": cv, "h": h.astype(cache["h"].dtype)}


class HymbaLM:
    """Segments: g0 | swa_a (scan) | g_mid | swa_b (scan) | g_last."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        fa = sorted(cfg.full_attn_layers) or [0, cfg.n_layers // 2,
                                              cfg.n_layers - 1]
        assert len(fa) == 3 and fa[0] == 0 and fa[2] == cfg.n_layers - 1, fa
        self.glob = fa
        self.n_swa_a = fa[1] - 1
        self.n_swa_b = cfg.n_layers - fa[1] - 2

    def init(self, rng):
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        ks = jax.random.split(rng, 8)
        bi = lambda k: block_init(k, cfg)
        params = {
            "embed": L.embedding_init(ks[1], cfg.vocab, cfg.d_model, dt),
            "g0": bi(ks[0]),
            "swa_a": jax.vmap(bi)(jax.random.split(ks[4], self.n_swa_a)),
            "g_mid": bi(ks[5]),
            "swa_b": jax.vmap(bi)(jax.random.split(ks[6], self.n_swa_b)),
            "g_last": bi(ks[7]),
            "final_norm": L.rmsnorm_init(ks[2], cfg.d_model, dt),
            "head": L.linear_init(ks[3], cfg.d_model, cfg.vocab, dt),
        }
        if cfg.meta_tokens:
            params["meta"] = {"m": L.normal_init(
                ks[2], (cfg.meta_tokens, cfg.d_model), dt, 0.02)}
        return params

    def _scan_seg(self, params, tape, x, cos, sin, name):
        cfg = self.cfg
        sub = tape.subtaps(name)
        tapped = sub is not None

        def block(p_l, t_l, xx):
            return subtape_run(
                lambda pp, tp: block_apply(pp, tp, xx, cfg, cos, sin,
                                           cfg.window),
                p_l, t_l, collect=tape.collect)

        run = jax.checkpoint(block) if cfg.remat else block

        def body(xx, xs):
            p_l, taps_l = xs
            out, aux = run(p_l, taps_l if tapped else None, xx)
            return out, aux

        blocks = fix_scan_params(params[name], tapped)
        x, (acts, tapz) = jax.lax.scan(body, x,
                                       (blocks, sub if tapped else {}))
        tape.merge_stacked(name, acts, tapz)
        return x

    def _trunk(self, params, tape, x, cos, sin):
        cfg = self.cfg
        with tape.scope("g0"):
            x = block_apply(params["g0"], tape, x, cfg, cos, sin, 0)
        x = self._scan_seg(params, tape, x, cos, sin, "swa_a")
        with tape.scope("g_mid"):
            x = block_apply(params["g_mid"], tape, x, cfg, cos, sin, 0)
        x = self._scan_seg(params, tape, x, cos, sin, "swa_b")
        with tape.scope("g_last"):
            x = block_apply(params["g_last"], tape, x, cfg, cos, sin, 0)
        return x

    def _embed(self, params, tape, tokens):
        cfg = self.cfg
        B = tokens.shape[0]
        x = L.embedding(tape, "embed", params["embed"], tokens)
        n_meta = 0
        if cfg.meta_tokens:
            meta = params["meta"]["m"]
            if meta.ndim == 2:
                meta = jnp.broadcast_to(meta, (B,) + meta.shape)
            x = jnp.concatenate([meta.astype(x.dtype), x], axis=1)
            n_meta = cfg.meta_tokens
        return x, n_meta

    def apply(self, params, batch, tape: Tape):
        cfg = self.cfg
        tokens = batch["tokens"]
        x, n_meta = self._embed(params, tape, tokens)
        cos, sin = L.rope_freqs(cfg.hd, x.shape[1], cfg.rope_theta)
        x = self._trunk(params, tape, x, cos, sin)
        x = L.rmsnorm(params["final_norm"], x)
        logits = L.linear(tape, "head", params["head"], x)[:, n_meta:, :]
        mask = batch.get("mask")
        mask = mask[:, 1:] if mask is not None else None
        return L.lm_per_sample_loss(logits[:, :-1], tokens[:, 1:], mask)

    def prefill(self, params, tokens):
        """Serving prefill -> last-position logits (B,V)."""
        cfg = self.cfg
        tape = Tape.null()
        x, _ = self._embed(params, tape, tokens)
        cos, sin = L.rope_freqs(cfg.hd, x.shape[1], cfg.rope_theta)
        x = self._trunk(params, tape, x, cos, sin)
        x = L.rmsnorm(params["final_norm"], x)
        return L.linear(tape, "head", params["head"], x[:, -1:, :])[:, 0]

    def init_cache(self, B, Scap, dtype=None):
        cfg = self.cfg
        dt = jnp.dtype(dtype or cfg.dtype)
        K, h = cfg.n_kv_heads, cfg.hd

        def seg(n):
            lead = (n,) if n is not None else ()
            return {"k": jnp.zeros(lead + (B, Scap, K, h), dt),
                    "v": jnp.zeros(lead + (B, Scap, K, h), dt),
                    "h": jnp.zeros(lead + (B, cfg.ssm_heads, cfg.hd,
                                           cfg.ssm_state), jnp.float32)}

        return {"g0": seg(None), "swa_a": seg(self.n_swa_a),
                "g_mid": seg(None), "swa_b": seg(self.n_swa_b),
                "g_last": seg(None)}

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        tape = Tape.null()
        Scap = cache["g0"]["k"].shape[1]
        cos, sin = L.rope_freqs(cfg.hd, Scap, cfg.rope_theta)
        x = L.embedding(tape, "embed", params["embed"], tokens[:, None])
        new_cache = {}

        def seg_scan(xx, name):
            def body(xx, xs):
                p_l, c_l = xs
                out, c_l = block_decode(p_l, tape, xx, c_l, pos, cfg, cos,
                                        sin, cfg.window)
                return out, c_l

            return jax.lax.scan(body, xx, (params[name], cache[name]))

        x, c = block_decode(params["g0"], tape, x, cache["g0"], pos, cfg,
                            cos, sin, 0)
        new_cache["g0"] = c
        x, new_cache["swa_a"] = seg_scan(x, "swa_a")
        x, c = block_decode(params["g_mid"], tape, x, cache["g_mid"], pos,
                            cfg, cos, sin, 0)
        new_cache["g_mid"] = c
        x, new_cache["swa_b"] = seg_scan(x, "swa_b")
        x, c = block_decode(params["g_last"], tape, x, cache["g_last"], pos,
                            cfg, cos, sin, 0)
        new_cache["g_last"] = c
        x = L.rmsnorm(params["final_norm"], x)
        logits = L.linear(tape, "head", params["head"], x)
        return logits[:, 0, :], new_cache
