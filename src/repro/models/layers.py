"""Tap-aware layer library (pure JAX, no flax).

Params are nested dicts. Generalized-linear ops (linear / embedding / moe)
route through the Tape; every other parameter (bias, norm scale, decay
vector, ...) may arrive with a leading per-sample batch axis when the DP
engine is differentiating it — layers align such params with ``align``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------- init
def normal_init(rng, shape, dtype, stddev):
    return (jax.random.normal(rng, shape, jnp.float32) * stddev).astype(dtype)


def lecun_init(rng, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[-2]
    return normal_init(rng, shape, dtype, 1.0 / math.sqrt(fan_in))


def zeros_init(rng, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(rng, shape, dtype):
    return jnp.ones(shape, dtype)


# ------------------------------------------------------------ psp alignment
def align(p: jnp.ndarray, x: jnp.ndarray, feature_ndim: int = 1) -> jnp.ndarray:
    """Align a vector param to x for broadcasting.

    p is either its declared shape (feature_ndim trailing dims) or that shape
    with a leading per-sample batch axis (DP psp route). x has batch first.
    """
    if p.ndim == feature_ndim:
        return p
    # (B, *features) -> (B, 1, ..., 1, *features)
    ones = (1,) * (x.ndim - 1 - feature_ndim)
    return p.reshape(p.shape[0], *ones, *p.shape[1:])


# -------------------------------------------------------------------- linear
def linear_init(rng, d_in, d_out, dtype, bias=False, scale=None):
    p = {"w": normal_init(rng, (d_in, d_out), dtype,
                          scale if scale is not None else 1.0 / math.sqrt(d_in))}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(tape, name, p, x):
    """x (B, ..., T, d) @ w (d, p) [+ b]. Tap + record on the matmul output."""
    s = jnp.einsum("...d,dp->...p", x, p["w"])
    s = tape.record(name, "mm", s, x)
    if "b" in p:
        s = s + align(p["b"], s)
    return s


# ----------------------------------------------------------------- embedding
def embedding_init(rng, vocab, d, dtype):
    return {"w": normal_init(rng, (vocab, d), dtype, 1.0)}


def embedding(tape, name, p, ids):
    """ids (B, T) -> (B, T, d); ghost-norm record is the id array."""
    s = jnp.take(p["w"], ids, axis=0)
    return tape.record(name, "emb", s, ids)


# --------------------------------------------------------------------- norms
def rmsnorm_init(rng, d, dtype):
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    nrm = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (nrm * align(p["g"], x).astype(jnp.float32)).astype(x.dtype)


def layernorm_init(rng, d, dtype):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    nrm = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = nrm * align(p["g"], x).astype(jnp.float32) + align(p["b"], x).astype(jnp.float32)
    return out.astype(x.dtype)


# ------------------------------------------------------------- convolutions
def conv2d_init(rng, kh, kw, c_in, c_out, dtype, bias=False):
    fan_in = kh * kw * c_in
    p = {"w": normal_init(rng, (kh * kw * c_in, c_out), dtype,
                          1.0 / math.sqrt(fan_in))}
    if bias:
        p["b"] = jnp.zeros((c_out,), dtype)
    return p


def conv2d(tape, name, p, x, kh, kw, stride=1, padding="SAME"):
    """NHWC conv as an im2col generalized-linear op (paper Sec. 2.1 / Bu et
    al. 2022a): patches (B, H'*W', kh*kw*C) are the activation record, so
    the ghost-norm / mixed-ghost machinery applies to convs unchanged —
    T = H'*W' is exactly the feature dimension of Tables 4/10.

    x (B,H,W,C) -> (B,H',W',c_out)."""
    B = x.shape[0]
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    Ho, Wo = patches.shape[1], patches.shape[2]
    a = patches.reshape(B, Ho * Wo, -1)          # (B, T, kh*kw*C)
    s = jnp.einsum("btd,dp->btp", a, p["w"])
    s = tape.record(name, "mm", s, a)
    if "b" in p:
        s = s + align(p["b"], s)
    return s.reshape(B, Ho, Wo, -1)


def conv1d_init(rng, k, c_in, c_out, dtype, bias=False):
    return conv2d_init(rng, 1, k, c_in, c_out, dtype, bias)


def conv1d(tape, name, p, x, k, stride=1, padding="SAME"):
    """x (B,T,C) -> (B,T',c_out) via the conv2d path."""
    out = conv2d(tape, name, p, x[:, None], 1, k, stride, padding)
    return out[:, 0]


# ---------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, max_T: int, theta: float = 10000.0):
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    t = jnp.arange(max_T, dtype=jnp.float32)
    freqs = jnp.outer(t, inv)  # (T, hd/2)
    return jnp.cos(freqs), jnp.sin(freqs)


def apply_rope(x, cos, sin, positions=None):
    """x (B, T, H, hd); cos/sin (maxT, hd/2); positions (B, T) optional."""
    if positions is not None:
        cos = cos[positions]  # (B,T,hd/2)
        sin = sin[positions]
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    else:
        T = x.shape[1]
        cos, sin = cos[None, :T, None, :], sin[None, :T, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- loss heads
def lm_per_sample_loss(logits, labels, mask=None):
    """Mean token cross-entropy per sample. logits (B,T,V), labels (B,T)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold  # (B,T)
    if mask is None:
        return jnp.mean(nll, axis=-1)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask, axis=-1) / jnp.maximum(jnp.sum(mask, axis=-1), 1.0)
