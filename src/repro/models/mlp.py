"""MLP classifier — the paper's Figure 2 / Figure 9 ablation model, and the
smallest end-to-end exercise of the tap machinery."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclass(frozen=True)
class MLPConfig:
    d_in: int = 32
    width: int = 64
    depth: int = 3
    n_classes: int = 10
    bias: bool = True
    dtype: str = "float32"


class MLP:
    def __init__(self, cfg: MLPConfig):
        self.cfg = cfg

    def init(self, rng):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        keys = jax.random.split(rng, cfg.depth + 1)
        params = {}
        d = cfg.d_in
        for i in range(cfg.depth):
            params[f"l{i}"] = L.linear_init(keys[i], d, cfg.width, dt, bias=cfg.bias)
            d = cfg.width
        params["head"] = L.linear_init(keys[-1], d, cfg.n_classes, dt, bias=cfg.bias)
        return params

    def apply(self, params, batch, tape):
        """batch: {'x': (B, d_in), 'y': (B,)} -> per-sample losses (B,)."""
        x = batch["x"][:, None, :]  # (B, 1, d) — T=1 canonical layout
        for i in range(self.cfg.depth):
            x = L.linear(tape, f"l{i}", params[f"l{i}"], x)
            x = jax.nn.relu(x)
        logits = L.linear(tape, "head", params["head"], x)[:, 0, :]
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
        return logz - gold
