"""Mamba2-style selective SSM head (scalar-A-per-head, shared B/C):

    h_t = exp(A dt_t) h_{t-1} + dt_t (x_t ⊗ B_t)     h (heads, hd, N)
    y_t = h_t C_t + D x_t,   gated by silu(z_t)

Used as the parallel-SSM branch of Hymba blocks. Projections are tapped;
A_log / D / dt_bias are per-sample (psp) vector params.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def ssm_init(rng, cfg: ModelConfig):
    d, heads, hd, N = cfg.d_model, cfg.ssm_heads, cfg.hd, cfg.ssm_state
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(rng)
    return {
        "xz": L.linear_init(k1, d, 2 * heads * hd, dt),
        "bcdt": L.linear_init(k2, d, 2 * N + heads, dt),
        "A_log": jnp.zeros((heads,), dt),
        "D": jnp.ones((heads,), dt),
        "dt_bias": jnp.zeros((heads,), dt),
    }


def _inputs(p, tape, xn, cfg: ModelConfig):
    heads, hd, N = cfg.ssm_heads, cfg.hd, cfg.ssm_state
    B, T, _ = xn.shape
    xz = L.linear(tape, "xz", p["xz"], xn)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = xs.reshape(B, T, heads, hd)
    bcdt = L.linear(tape, "bcdt", p["bcdt"], xn).astype(jnp.float32)
    B_, C_, dtr = jnp.split(bcdt, [N, 2 * N], axis=-1)
    dt_bias = L.align(p["dt_bias"], dtr).astype(jnp.float32)
    dtv = jax.nn.softplus(dtr + dt_bias)                       # (B,T,heads)
    A = -jnp.exp(L.align(p["A_log"], dtv).astype(jnp.float32))
    dA = jnp.exp(A * dtv)                                      # (B,T,heads)
    return xs, z, B_, C_, dtv, dA


def ssm_apply(p, tape, xn, cfg: ModelConfig):
    """xn (B,T,d) -> (B,T,heads*hd)."""
    heads, hd = cfg.ssm_heads, cfg.hd
    B, T, _ = xn.shape
    xs, z, B_, C_, dtv, dA = _inputs(p, tape, xn, cfg)
    x32 = xs.astype(jnp.float32)

    def step(h, inp):
        x_t, b_t, c_t, dt_t, da_t = inp
        h = (da_t[:, :, None, None] * h
             + dt_t[:, :, None, None] * (x_t[..., None] * b_t[:, None, None, :]))
        y = jnp.einsum("bhpn,bn->bhp", h, c_t)
        return h, y

    h0 = jnp.zeros((B, heads, hd, cfg.ssm_state), jnp.float32)
    xs_t = tuple(jnp.moveaxis(t, 1, 0) for t in (x32, B_, C_, dtv, dA))
    _, y = jax.lax.scan(step, h0, xs_t)
    y = jnp.moveaxis(y, 0, 1)                                  # (B,T,heads,hd)
    D = L.align(p["D"], dtv).astype(jnp.float32)
    y = y + D[..., None] * x32
    y = y * jax.nn.silu(z.astype(jnp.float32)).reshape(B, T, heads, hd)
    return y.reshape(B, T, heads * hd).astype(xn.dtype)


def ssm_decode(p, tape, xn, h, cfg: ModelConfig):
    """xn (B,1,d); h (B,heads,hd,N) -> (y (B,1,heads*hd), h')."""
    heads, hd = cfg.ssm_heads, cfg.hd
    B = xn.shape[0]
    xs, z, B_, C_, dtv, dA = _inputs(p, tape, xn, cfg)
    x_t = xs.astype(jnp.float32)[:, 0]
    b_t, c_t, dt_t, da_t = B_[:, 0], C_[:, 0], dtv[:, 0], dA[:, 0]
    h = (da_t[:, :, None, None] * h.astype(jnp.float32)
         + dt_t[:, :, None, None] * (x_t[..., None] * b_t[:, None, None, :]))
    y = jnp.einsum("bhpn,bn->bhp", h, c_t)
    D = p["D"].astype(jnp.float32)  # decode never runs the psp route
    y = y + D[..., None] * x_t
    y = y * jax.nn.silu(z.astype(jnp.float32)).reshape(B, 1, heads, hd)[:, 0]
    return y.reshape(B, 1, heads * hd).astype(xn.dtype), h
