"""Batched serving example: prefill + autoregressive decode with a KV cache
(greedy), on a reduced config of any zoo architecture.

    PYTHONPATH=src python examples/serve_decode.py [arch]
"""
import sys

import jax
import jax.numpy as jnp

from repro.configs.registry import build, smoke_config
from repro.launch.serve import generate


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "hymba-1.5b"
    cfg = smoke_config(arch).with_(dtype="float32", param_dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, cfg.vocab)
    out = generate(model, params, prompts, gen_len=8)
    assert out.shape == (4, 16)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab)))
    print(f"{arch}: generated {out.shape}")
    print(out)


if __name__ == "__main__":
    main()
