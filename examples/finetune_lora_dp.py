"""DP-LoRA (paper Appendix E.2): BK applied to parameter-efficient
fine-tuning. The base weights are frozen (closed over); only the low-rank
A/B adapters are trained — each adapter matmul is a tapped generalized-linear
op, so the ghost-norm/book-keeping machinery applies unchanged, with the
paper's complexity (space 4BT^2 vs Br(p+d) for instantiation).

    PYTHONPATH=src python examples/finetune_lora_dp.py
"""
import jax
import jax.numpy as jnp

from repro.core.bk import DPConfig
from repro.core.engine import make_grad_fn
from repro.core.tape import Tape
from repro.models import layers as L

D, FF, V, RANK, B, T = 64, 128, 256, 8, 8, 16


def init_base(rng):
    ks = jax.random.split(rng, 4)
    return {
        "embed": L.embedding_init(ks[0], V, D, jnp.float32),
        "up": L.linear_init(ks[1], D, FF, jnp.float32),
        "down": L.linear_init(ks[2], FF, D, jnp.float32),
        "head": L.linear_init(ks[3], D, V, jnp.float32),
    }


def init_lora(rng):
    ks = jax.random.split(rng, 4)
    z = jnp.zeros
    lora = lambda k, din, dout: {
        "A": {"w": L.normal_init(k, (din, RANK), jnp.float32, 0.02)},
        "B": {"w": z((RANK, dout), jnp.float32)},
    }
    return {"up": lora(ks[0], D, FF), "down": lora(ks[1], FF, D)}


def lora_linear(tape, name, frozen_w, lp, x, scale=2.0):
    """x @ (W_frozen + A B * scale) with taps on both adapter matmuls."""
    base = jnp.einsum("...d,dp->...p", x, frozen_w)
    u = L.linear(tape, f"{name}/A", lp["A"], x)
    v = L.linear(tape, f"{name}/B", lp["B"], u)
    return base + scale * v


def make_apply(base):
    def apply(lora_params, batch, tape: Tape):
        x = jnp.take(base["embed"]["w"], batch["tokens"], axis=0)  # frozen
        h = lora_linear(tape, "up", base["up"]["w"], lora_params["up"], x)
        h = jax.nn.gelu(h)
        h = lora_linear(tape, "down", base["down"]["w"], lora_params["down"], h)
        logits = jnp.einsum("btd,dv->btv", x + h, base["head"]["w"])
        return L.lm_per_sample_loss(logits[:, :-1], batch["tokens"][:, 1:])

    return apply


def main():
    base = init_base(jax.random.PRNGKey(0))
    lora = init_lora(jax.random.PRNGKey(1))
    apply_fn = make_apply(base)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, V)}

    grad_fn = jax.jit(make_grad_fn(apply_fn, DPConfig(
        mode="bk", clipping="automatic", sigma=0.5)))
    # sanity: BK == Opacus on the adapter params
    ref_fn = jax.jit(make_grad_fn(apply_fn, DPConfig(
        mode="opacus", clipping="automatic", sigma=0.5)))
    g1, a1 = grad_fn(lora, batch, jax.random.PRNGKey(3))
    g2, a2 = ref_fn(lora, batch, jax.random.PRNGKey(3))
    import numpy as np
    np.testing.assert_allclose(a1["per_sample_norms"], a2["per_sample_norms"],
                               rtol=1e-4)
    print("DP-LoRA: BK == Opacus on adapters; norms",
          np.asarray(a1["per_sample_norms"])[:4])

    lr = 1e-2
    for step in range(10):
        grads, aux = grad_fn(lora, batch, jax.random.fold_in(
            jax.random.PRNGKey(4), step))
        lora = jax.tree_util.tree_map(lambda p, g: p - lr * g, lora, grads)
        if step % 3 == 0:
            print(f"step {step}: loss {float(aux['loss']):.4f}")
    print("OK — DP-LoRA fine-tuning with Book-Keeping.")


if __name__ == "__main__":
    main()
