"""DP-LoRA (paper Appendix E.2) via PrivacyPolicy frozen groups: the base
model and the low-rank adapters live in ONE params tree; the policy freezes
the base (``trainable=False`` — no tap differentiation, no per-sample norm,
no weighted grad, no noise: zero book-keeping cost, the LoRA fast path) and
clips the A/B adapters group-wise with their own thresholds.

The kernel_report shows the frozen taps are truly gone — the engine does no
work for them — and the adapter gradients still agree with an Opacus-style
per-sample reference that honors the same policy.

    PYTHONPATH=src python examples/finetune_lora_dp.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import PrivacyEngine, make_grad_fn
from repro.core.policy import ParamGroup, PrivacyPolicy
from repro.core.tape import Tape
from repro.models import layers as L

D, FF, V, RANK, B, T = 64, 128, 256, 8, 8, 16


def init_params(rng):
    ks = jax.random.split(rng, 6)
    z = jnp.zeros
    lora = lambda k, din, dout: {
        "A": {"w": L.normal_init(k, (din, RANK), jnp.float32, 0.02)},
        "B": {"w": z((RANK, dout), jnp.float32)},
    }
    return {
        "base": {
            "embed": L.embedding_init(ks[0], V, D, jnp.float32),
            "up": L.linear_init(ks[1], D, FF, jnp.float32),
            "down": L.linear_init(ks[2], FF, D, jnp.float32),
            "head": L.linear_init(ks[3], D, V, jnp.float32),
        },
        "lora": {"up": lora(ks[4], D, FF), "down": lora(ks[5], FF, D)},
    }


def lora_linear(tape, name, base_p, lora_p, x, scale=2.0):
    """x @ (W_base + A B * scale); base AND adapter matmuls are all tapped —
    the policy decides which of them do DP book-keeping."""
    with tape.scope("base"):
        h = L.linear(tape, name, base_p, x)
    with tape.scope("lora"):
        u = L.linear(tape, f"{name}/A", lora_p["A"], x)
        v = L.linear(tape, f"{name}/B", lora_p["B"], u)
    return h + scale * v


def apply_fn(params, batch, tape: Tape):
    base, lora = params["base"], params["lora"]
    with tape.scope("base"):
        x = L.embedding(tape, "embed", base["embed"], batch["tokens"])
    h = lora_linear(tape, "up", base["up"], lora["up"], x)
    h = jax.nn.gelu(h)
    h = lora_linear(tape, "down", base["down"], lora["down"], h)
    with tape.scope("base"):
        logits = L.linear(tape, "head", base["head"], x + h)
    return L.lm_per_sample_loss(logits[:, :-1], batch["tokens"][:, 1:])


POLICY = PrivacyPolicy(groups=(
    # adapters: each matrix family group-wise clipped to its own R_g;
    # sensitivity composes as sqrt(R_A^2 + R_B^2)
    ParamGroup("lora_A", r"lora/.*/A/.*", R=0.7, scope="group"),
    ParamGroup("lora_B", r"lora/.*/B/.*", R=0.7, scope="group"),
    # frozen base: no taps, no norms, no noise — zero grads come back
    ParamGroup("base", "base", trainable=False),
), mode="bk", sigma=0.5)


def main():
    params = init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, V)}

    engine = PrivacyEngine(apply_fn, POLICY)
    report = engine.kernel_report(params, batch)
    assert not any(k.startswith("base/") for k in report), report
    print(f"kernel_report taps (base frozen, adapters only): {sorted(report)}")

    grad_fn = jax.jit(engine.grad)
    # sanity: BK == Opacus under the SAME policy, and base grads are zero
    import dataclasses
    ref_fn = jax.jit(make_grad_fn(apply_fn,
                                  dataclasses.replace(POLICY, mode="opacus")))
    g1, a1 = grad_fn(params, batch, jax.random.PRNGKey(3))
    g2, a2 = ref_fn(params, batch, jax.random.PRNGKey(3))
    for gname in ("lora_A", "lora_B"):
        np.testing.assert_allclose(a1["group_norms"][gname],
                                   a2["group_norms"][gname], rtol=1e-4)
    assert all(np.all(np.asarray(x) == 0)
               for x in jax.tree_util.tree_leaves(g1["base"]))
    print("DP-LoRA: BK == Opacus on adapters; zero base grads; group norms",
          {k: np.asarray(v)[:2] for k, v in a1["group_norms"].items()})

    lr = 1e-2
    for step in range(10):
        grads, aux = grad_fn(params, batch,
                             jax.random.fold_in(jax.random.PRNGKey(4), step))
        params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
        if step % 3 == 0:
            print(f"step {step}: loss {float(aux['loss']):.4f}")
    print("OK — DP-LoRA fine-tuning with a frozen-group PrivacyPolicy.")


if __name__ == "__main__":
    main()
