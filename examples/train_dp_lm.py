"""End-to-end driver: DP-train a ~100M-param GPT2-class LM for a few hundred
steps with checkpoint/restart, gradient accumulation, and the RDP accountant.

Full run (a few hours on this CPU container; minutes on one TPU host):
    PYTHONPATH=src python examples/train_dp_lm.py
Smoke run:
    PYTHONPATH=src python examples/train_dp_lm.py --smoke
DP-FTRL instead of DP-SGD-style AdamW (tree-aggregation noise, epoch
restarts with Honaker completion — amplification-free privacy, no Poisson
sampling assumption):
    PYTHONPATH=src python examples/train_dp_lm.py --smoke --ftrl
"""
import argparse

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.bk import DPConfig
from repro.launch.train import train


def gpt2_100m() -> ModelConfig:
    # ~104M params: 12L, d=768, vocab=50257 — GPT2-small class
    return ModelConfig(name="gpt2-100m", family="dense", n_layers=12,
                       d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
                       d_ff=3072, vocab=50257, norm="layernorm", act="gelu",
                       max_t=512)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ftrl", action="store_true",
                    help="momentum DP-FTRL + tree-aggregation noise with "
                         "epoch restarts and Honaker completion")
    args = ap.parse_args()

    if args.smoke:
        cfg = gpt2_100m().with_(n_layers=2, d_model=64, n_heads=4,
                                n_kv_heads=4, head_dim=16, d_ff=128,
                                vocab=512)
        tc = TrainConfig(global_batch=8, microbatch=4, seq_len=32,
                        steps=args.steps or 20, lr=1e-3,
                        checkpoint_dir="/tmp/repro_dp_lm", checkpoint_every=10)
    else:
        cfg = gpt2_100m()
        tc = TrainConfig(global_batch=64, microbatch=16, seq_len=256,
                        steps=args.steps or 300, lr=3e-4, warmup=20,
                        checkpoint_dir="/tmp/repro_dp_lm", checkpoint_every=50)

    if args.ftrl:
        # restart the tree (and the FTRL anchor) every ~quarter of the run;
        # train() switches the noise mechanism to 'tree' automatically
        import dataclasses
        tc = dataclasses.replace(tc, optimizer="ftrl", ftrl_momentum=0.9,
                                 restart_every=max(2, tc.steps // 4),
                                 tree_completion=True, weight_decay=0.0,
                                 # constant schedule discards warmup: FTRL
                                 # rescales the whole prefix by lr_t, so
                                 # neither decay nor ramp applies
                                 lr_schedule="constant", warmup=0)

    dp = DPConfig(mode="bk-mixopt", clipping="automatic", R=1.0)
    params, losses = train(cfg, tc, dp, dataset_size=100_000,
                           target_epsilon=3.0)
    assert losses[-1] < losses[0], "loss should decrease under DP training"
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps (eps<=3.0)")


if __name__ == "__main__":
    main()
