"""End-to-end driver: DP-train a ~100M-param GPT2-class LM for a few hundred
steps with checkpoint/restart, gradient accumulation, and the RDP accountant.

Full run (a few hours on this CPU container; minutes on one TPU host):
    PYTHONPATH=src python examples/train_dp_lm.py
Smoke run:
    PYTHONPATH=src python examples/train_dp_lm.py --smoke
"""
import argparse

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.bk import DPConfig
from repro.launch.train import train


def gpt2_100m() -> ModelConfig:
    # ~104M params: 12L, d=768, vocab=50257 — GPT2-small class
    return ModelConfig(name="gpt2-100m", family="dense", n_layers=12,
                       d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
                       d_ff=3072, vocab=50257, norm="layernorm", act="gelu",
                       max_t=512)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    if args.smoke:
        cfg = gpt2_100m().with_(n_layers=2, d_model=64, n_heads=4,
                                n_kv_heads=4, head_dim=16, d_ff=128,
                                vocab=512)
        tc = TrainConfig(global_batch=8, microbatch=4, seq_len=32,
                        steps=args.steps or 20, lr=1e-3,
                        checkpoint_dir="/tmp/repro_dp_lm", checkpoint_every=10)
    else:
        cfg = gpt2_100m()
        tc = TrainConfig(global_batch=64, microbatch=16, seq_len=256,
                        steps=args.steps or 300, lr=3e-4, warmup=20,
                        checkpoint_dir="/tmp/repro_dp_lm", checkpoint_every=50)

    dp = DPConfig(mode="bk-mixopt", clipping="automatic", R=1.0)
    params, losses = train(cfg, tc, dp, dataset_size=100_000,
                           target_epsilon=3.0)
    assert losses[-1] < losses[0], "loss should decrease under DP training"
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps (eps<=3.0)")


if __name__ == "__main__":
    main()
