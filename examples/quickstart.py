"""Quickstart: the paper's Sec. 4 usage pattern, JAX-style.

Swap a standard training step for its DP version by choosing a
clipping_mode — same optimizer, same accuracy semantics, BK cost profile.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.registry import build, smoke_config
from repro.core.bk import DPConfig
from repro.core.engine import PrivacyEngine
from repro.data.synthetic import make_batch
from repro.optim.optimizers import make_optimizer

# 1. a model from the zoo (reduced config so this runs on CPU in seconds)
cfg = smoke_config("qwen2-1.5b").with_(dtype="float32", param_dtype="float32")
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))

# 2. a PrivacyEngine: pick the implementation ('bk-mixopt' = the paper's
#    hybrid BK) and the privacy budget; sigma is calibrated via the RDP
#    accountant exactly as the paper's codebase does.
engine = PrivacyEngine(
    model.apply,
    DPConfig(mode="bk-mixopt", clipping="automatic", R=1.0),
    batch_size=16, dataset_size=50_000, epochs=3, target_epsilon=3.0)
print(f"accountant: sigma={engine.cfg.sigma:.3f} -> "
      f"eps={engine.budget.epsilon:.2f} at delta={engine.budget.delta}")

# 3. the usual training loop — engine.grad is a drop-in for jax.grad
opt = make_optimizer("adamw", lambda s: jnp.asarray(1e-3))
opt_state = opt.init(params)
step_fn = jax.jit(lambda p, o, i, b, r: (lambda g, aux: (
    *opt.update(g, o, p, i), aux["loss"]))(*engine.grad(p, b, r)))

for step in range(5):
    batch = make_batch(cfg, B=16, T=32, seed=0, step=step)
    rng = jax.random.fold_in(jax.random.PRNGKey(1), step)
    params, opt_state, loss = step_fn(params, opt_state, jnp.asarray(step),
                                      batch, rng)
    print(f"step {step}: private loss {float(loss):.4f}")
print("OK — differentially private training with Book-Keeping.")
