"""Step-level train benchmark: the REAL jitted, donated, mesh-lowered train
step (launch.steps.make_train_step) per (dp mode x tape policy x device
count), with a regression gate against the committed baseline.

    PYTHONPATH=src python -m benchmarks.step_bench [--fast]
    PYTHONPATH=src python -m benchmarks.step_bench --cell bk-mixopt 8 native

The parent process spawns one subprocess per device count (XLA_FLAGS'
--xla_force_host_platform_device_count must be set before jax imports), and
merges the per-cell records into ``BENCH_step.json``:

  steps_per_s / tokens_per_s   measured wall time over ``steps`` donated
                               steps (after one compile+warmup call);
  peak_hbm_bytes               compiled.memory_analysis(): per-device
                               argument + output + temp bytes (and XLA's own
                               peak estimate when the backend reports one);
  cost                         utils.hlo.xla_cost_analysis(compiled) —
                               flops / bytes accessed per device;
  tape                         the tape residency policy the cell ran
                               (bk-mixopt runs one cell per policy at 1
                               device — the temp-HBM column IS the held
                               book-kept state the residency manager frees).

Gate: when a same-backend ``BENCH_step.json`` already exists (the committed
baseline), matching cells regress the run if tokens/s drops or per-device
peak-HBM (argument+output+temp) rises by more than STEP_GATE_TOL (default
10%). STEP_GATE=0 disables; new cells without a baseline counterpart only
report. On CPU the wall numbers are correctness-path (Pallas interpret
mode), not a TPU projection — the tracked signal is the per-device memory
trajectory and the mode/tape/device ratios. Kernel microbenches live in
kernel_bench.py; this file is the end-to-end step truth.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

# (mode, tape policy, device count, config profile, clipping scope). 'smoke'
# is the committed-baseline geometry (2 layers — residency constants
# dominate, so only bf16 wins there); 'deep' (8 layers, d=64, T=64) is where
# the book-kept state dominates and the residency manager's asymptotics
# show. scope 'layer' re-scopes the flat DPConfig to per-path clip units
# (policy.with_scope): every tap streams — one fused norm+clip+grad at the
# cotangent, nothing book-kept — so its deep-profile temp HBM is the
# engine's floor (below even 'recompute', without the re-derivation pass).
CELLS = (
    ("nonprivate", "native", 1, "smoke", "flat"),
    ("bk-mixopt", "native", 1, "smoke", "flat"),
    ("bk-mixopt", "bf16", 1, "smoke", "flat"),
    ("bk-mixopt", "int8", 1, "smoke", "flat"),
    ("bk-mixopt", "recompute", 1, "smoke", "flat"),
    ("bk-mixopt", "native", 1, "smoke", "layer"),
    ("nonprivate", "native", 8, "smoke", "flat"),
    ("bk-mixopt", "native", 8, "smoke", "flat"),
    ("bk-mixopt", "native", 1, "deep", "flat"),
    ("bk-mixopt", "bf16", 1, "deep", "flat"),
    ("bk-mixopt", "recompute", 1, "deep", "flat"),
    ("bk-mixopt", "native", 1, "deep", "layer"),
)
OUT = "BENCH_step.json"


def run_cell(mode: str, ndev: int, fast: bool, tape: str = "native",
             profile: str = "smoke", scope: str = "flat") -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs.registry import build, smoke_config
    from repro.core.bk import DPConfig
    from repro.data.pipeline import Pipeline, PipelineConfig
    from repro.launch.mesh import make_train_mesh
    from repro.launch.steps import TrainState, make_train_step
    from repro.optim.optimizers import make_optimizer
    from repro.utils.hlo import xla_cost_analysis

    assert len(jax.devices()) >= ndev, (len(jax.devices()), ndev)
    B, T, steps = (8, 32, 3) if fast else (16, 64, 10)
    cfg = smoke_config("qwen2-1.5b").with_(dtype="float32",
                                           param_dtype="float32")
    if profile == "deep":
        cfg = cfg.with_(n_layers=8, d_model=64, d_ff=96, max_t=128)
        T = 64
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer("adamw", lambda s: jnp.asarray(1e-3, jnp.float32))
    dp = DPConfig(mode=mode, sigma=0.0 if mode == "nonprivate" else 0.5,
                  tape_policy=tape, tape_chunks=2)
    if scope not in ("", "flat") and mode != "nonprivate":
        from repro.core.policy import with_scope
        dp = with_scope(dp, scope)
    mesh = make_train_mesh(ndev, 1)
    pipe = Pipeline(cfg, PipelineConfig(B, T, seed=0))

    step_fn, state_sh, batch_sh = make_train_step(
        model.apply, params, opt, "adamw", dp, 0, mesh, pipe.batch(0))
    jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
    state = TrainState(params=jax.device_put(params, state_sh.params),
                       opt_state=jax.device_put(opt.init(params),
                                                state_sh.opt_state),
                       step=jnp.asarray(0, jnp.int32),
                       rng=jax.random.PRNGKey(1))
    batch = jax.device_put(pipe.batch(0), batch_sh)

    # drive the lowered executable directly: jitted() after lower().compile()
    # would pay a SECOND full XLA compilation (lower() bypasses the jit
    # dispatch cache), doubling each cell's wall time on CPU
    compiled = jitted.lower(state, batch).compile()
    ma = compiled.memory_analysis()
    ca = xla_cost_analysis(compiled)

    state, loss = compiled(state, batch)        # warmup (donates like jitted)
    jax.block_until_ready(loss)
    t0 = time.perf_counter()
    for _ in range(steps):
        state, loss = compiled(state, batch)
    jax.block_until_ready(loss)
    elapsed = time.perf_counter() - t0

    return {
        "mode": mode, "devices": ndev, "tape": tape, "profile": profile,
        "scope": scope, "mesh": dict(mesh.shape),
        "backend": jax.default_backend(),
        "interpret_kernels": jax.default_backend() != "tpu",
        "batch": B, "seq": T, "steps": steps,
        "steps_per_s": steps / elapsed,
        "tokens_per_s": B * T * steps / elapsed,
        "final_loss": float(loss),
        "peak_hbm_bytes": {
            "argument": ma.argument_size_in_bytes,
            "output": ma.output_size_in_bytes,
            "temp": ma.temp_size_in_bytes,
            "peak": getattr(ma, "peak_memory_in_bytes", 0),
            "total": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                      + ma.temp_size_in_bytes),
        },
        "cost": {k: ca[k] for k in ("flops", "bytes accessed") if k in ca},
    }


def _load_baseline(backend: str, fast: bool):
    """The committed BENCH_step.json, iff it matches this run's backend and
    batch geometry (a cross-backend or fast-vs-full comparison gates
    nothing)."""
    if not os.path.exists(OUT):
        return None
    try:
        with open(OUT) as f:
            base = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    if base.get("backend") != backend or base.get("fast") != fast:
        return None
    return {(c["mode"], c.get("tape", "native"), c["devices"],
             c.get("profile", "smoke"), c.get("scope", "flat")): c
            for c in base.get("cells", [])}


def gate(cells: list, baseline: dict) -> list:
    """-> list of regression strings. A cell regresses when per-device
    peak-HBM rises by more than STEP_GATE_TOL (default 10% — the memory
    numbers are deterministic per backend) or tokens/s drops by more than
    STEP_GATE_TOKS_TOL (defaults to STEP_GATE_TOL; ci.sh widens it on CPU,
    where 3-step interpret-mode wall clocks jitter far past 10%) vs its
    same-(mode, tape, devices, profile) baseline cell."""
    tol = float(os.environ.get("STEP_GATE_TOL", "0.10"))
    toks_tol = float(os.environ.get("STEP_GATE_TOKS_TOL", str(tol)))
    bad = []
    for c in cells:
        key = (c["mode"], c.get("tape", "native"), c["devices"],
               c.get("profile", "smoke"), c.get("scope", "flat"))
        b = baseline.get(key)
        if b is None:
            continue
        name = f"{key[0]}/{key[1]}/{key[3]}/{key[4]} x {key[2]}dev"
        if c["tokens_per_s"] < b["tokens_per_s"] * (1 - toks_tol):
            bad.append(f"{name}: tokens/s {c['tokens_per_s']:.0f} < "
                       f"baseline {b['tokens_per_s']:.0f} - {toks_tol:.0%}")
        got_hbm = c["peak_hbm_bytes"]["total"]
        base_hbm = b["peak_hbm_bytes"]["total"]
        if got_hbm > base_hbm * (1 + tol):
            bad.append(f"{name}: peak-HBM/dev {got_hbm} > "
                       f"baseline {base_hbm} + {tol:.0%}")
    return bad


def main(argv) -> int:
    fast = "--fast" in argv
    if "--cell" in argv:
        i = argv.index("--cell")
        mode, ndev = argv[i + 1], int(argv[i + 2])
        rest = [a for a in argv[i + 3:] if not a.startswith("--")]
        tape = rest[0] if rest else "native"
        profile = rest[1] if len(rest) > 1 else "smoke"
        scope = rest[2] if len(rest) > 2 else "flat"
        print("CELL_JSON " + json.dumps(run_cell(mode, ndev, fast, tape,
                                                 profile, scope)))
        return 0

    cells = []
    baseline = None
    for mode, tape, ndev, profile, scope in CELLS:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count={ndev}"
                            ).strip()
        env["PYTHONPATH"] = "src" + (os.pathsep + env["PYTHONPATH"]
                                     if env.get("PYTHONPATH") else "")
        cmd = [sys.executable, "-m", "benchmarks.step_bench",
               "--cell", mode, str(ndev), tape, profile, scope] \
            + (["--fast"] if fast else [])
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           timeout=1800)
        line = next((ln for ln in r.stdout.splitlines()
                     if ln.startswith("CELL_JSON ")), None)
        if r.returncode != 0 or line is None:
            print(f"[ERR ] {mode}/{tape}/{profile}/{scope} x {ndev}dev:\n"
                  f"{r.stdout[-800:]}{r.stderr[-2000:]}")
            return 1
        cell = json.loads(line[len("CELL_JSON "):])
        if baseline is None:
            # read the committed file ONCE, before this run overwrites it
            baseline = _load_baseline(cell["backend"], fast) or {}
        cells.append(cell)
        hbm = cell["peak_hbm_bytes"]["total"] / 2**20
        temp = cell["peak_hbm_bytes"]["temp"] / 2**20
        print(f"[ok] {mode:>11}/{tape:<9}/{profile:<5}/{scope:<5} x {ndev}dev"
              f"  {cell['tokens_per_s']:>8.0f} tok/s  "
              f"{cell['steps_per_s']:>6.2f} steps/s  "
              f"hbm/dev {hbm:>6.2f} MiB (temp {temp:.2f})")

    out = {"backend": cells[0]["backend"], "fast": fast, "cells": cells}
    if os.environ.get("STEP_GATE", "1") != "0" and baseline:
        # gate BEFORE overwriting: a failing run must not replace the
        # committed baseline it regressed against (the regressed cells go
        # to a side file for inspection instead)
        bad = gate(cells, baseline)
        if bad:
            for b in bad:
                print(f"[GATE] REGRESSION {b}")
            with open(OUT + ".regressed", "w") as f:
                json.dump(out, f, indent=2)
            print(f"kept {OUT} (baseline); regressed cells in "
                  f"{OUT}.regressed")
            return 2
        print(f"[GATE] ok: {len(cells)} cells within "
              f"{float(os.environ.get('STEP_GATE_TOL', '0.10')):.0%} of the "
              "committed baseline")
    with open(OUT, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {OUT} ({len(cells)} cells)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
