"""Step-level train benchmark: the REAL jitted, donated, mesh-lowered train
step (launch.steps.make_train_step) per (dp mode x device count).

    PYTHONPATH=src python -m benchmarks.step_bench [--fast]
    PYTHONPATH=src python -m benchmarks.step_bench --cell bk-mixopt 8 [--fast]

The parent process spawns one subprocess per device count (XLA_FLAGS'
--xla_force_host_platform_device_count must be set before jax imports), and
merges the per-cell records into ``BENCH_step.json``:

  steps_per_s / tokens_per_s   measured wall time over ``steps`` donated
                               steps (after one compile+warmup call);
  peak_hbm_bytes               compiled.memory_analysis(): per-device
                               argument + output + temp bytes (and XLA's own
                               peak estimate when the backend reports one);
  cost                         utils.hlo.xla_cost_analysis(compiled) —
                               flops / bytes accessed per device.

On CPU the wall numbers are correctness-path (Pallas interpret mode), not a
TPU projection — the tracked signal is the per-device memory trajectory
(sharded state + slice-sized noise vs replicated) and the mode-vs-mode /
1-vs-N-device ratios. Kernel microbenches live in kernel_bench.py; this file
is the end-to-end step truth the perf trajectory was missing.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

MODES = ("nonprivate", "bk-mixopt")
DEVICE_COUNTS = (1, 8)
OUT = "BENCH_step.json"


def run_cell(mode: str, ndev: int, fast: bool) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs.registry import build, smoke_config
    from repro.core.bk import DPConfig
    from repro.data.pipeline import Pipeline, PipelineConfig
    from repro.launch.mesh import make_train_mesh
    from repro.launch.steps import TrainState, make_train_step
    from repro.optim.optimizers import make_optimizer
    from repro.utils.hlo import xla_cost_analysis

    assert len(jax.devices()) >= ndev, (len(jax.devices()), ndev)
    B, T, steps = (8, 32, 3) if fast else (16, 64, 10)
    cfg = smoke_config("qwen2-1.5b").with_(dtype="float32",
                                           param_dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer("adamw", lambda s: jnp.asarray(1e-3, jnp.float32))
    dp = DPConfig(mode=mode, sigma=0.0 if mode == "nonprivate" else 0.5)
    mesh = make_train_mesh(ndev, 1)
    pipe = Pipeline(cfg, PipelineConfig(B, T, seed=0))

    step_fn, state_sh, batch_sh = make_train_step(
        model.apply, params, opt, "adamw", dp, 0, mesh, pipe.batch(0))
    jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
    state = TrainState(params=jax.device_put(params, state_sh.params),
                       opt_state=jax.device_put(opt.init(params),
                                                state_sh.opt_state),
                       step=jnp.asarray(0, jnp.int32),
                       rng=jax.random.PRNGKey(1))
    batch = jax.device_put(pipe.batch(0), batch_sh)

    # drive the lowered executable directly: jitted() after lower().compile()
    # would pay a SECOND full XLA compilation (lower() bypasses the jit
    # dispatch cache), doubling each cell's wall time on CPU
    compiled = jitted.lower(state, batch).compile()
    ma = compiled.memory_analysis()
    ca = xla_cost_analysis(compiled)

    state, loss = compiled(state, batch)        # warmup (donates like jitted)
    jax.block_until_ready(loss)
    t0 = time.perf_counter()
    for _ in range(steps):
        state, loss = compiled(state, batch)
    jax.block_until_ready(loss)
    elapsed = time.perf_counter() - t0

    return {
        "mode": mode, "devices": ndev, "mesh": dict(mesh.shape),
        "backend": jax.default_backend(),
        "interpret_kernels": jax.default_backend() != "tpu",
        "batch": B, "seq": T, "steps": steps,
        "steps_per_s": steps / elapsed,
        "tokens_per_s": B * T * steps / elapsed,
        "final_loss": float(loss),
        "peak_hbm_bytes": {
            "argument": ma.argument_size_in_bytes,
            "output": ma.output_size_in_bytes,
            "temp": ma.temp_size_in_bytes,
            "peak": getattr(ma, "peak_memory_in_bytes", 0),
            "total": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                      + ma.temp_size_in_bytes),
        },
        "cost": {k: ca[k] for k in ("flops", "bytes accessed") if k in ca},
    }


def main(argv) -> int:
    fast = "--fast" in argv
    if "--cell" in argv:
        i = argv.index("--cell")
        mode, ndev = argv[i + 1], int(argv[i + 2])
        print("CELL_JSON " + json.dumps(run_cell(mode, ndev, fast)))
        return 0

    cells = []
    for ndev in DEVICE_COUNTS:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count={ndev}"
                            ).strip()
        env["PYTHONPATH"] = "src" + (os.pathsep + env["PYTHONPATH"]
                                     if env.get("PYTHONPATH") else "")
        for mode in MODES:
            cmd = [sys.executable, "-m", "benchmarks.step_bench",
                   "--cell", mode, str(ndev)] + (["--fast"] if fast else [])
            r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                               timeout=1800)
            line = next((ln for ln in r.stdout.splitlines()
                         if ln.startswith("CELL_JSON ")), None)
            if r.returncode != 0 or line is None:
                print(f"[ERR ] {mode} x {ndev}dev:\n{r.stdout[-800:]}"
                      f"{r.stderr[-2000:]}")
                return 1
            cell = json.loads(line[len("CELL_JSON "):])
            cells.append(cell)
            hbm = cell["peak_hbm_bytes"]["total"] / 2**20
            print(f"[ok] {mode:>11} x {ndev}dev  "
                  f"{cell['tokens_per_s']:>8.0f} tok/s  "
                  f"{cell['steps_per_s']:>6.2f} steps/s  "
                  f"hbm/dev {hbm:>7.1f} MiB")

    out = {"backend": cells[0]["backend"], "fast": fast, "cells": cells}
    with open(OUT, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {OUT} ({len(cells)} cells)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
