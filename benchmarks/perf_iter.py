import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Perf-iteration harness (EXPERIMENTS.md §Perf): re-lower a chosen cell
under a named variant, compare roofline terms against the recorded baseline.

    PYTHONPATH=src:. python -m benchmarks.perf_iter --arch llama3-405b \
        --shape train_4k --variant streamed

Each run appends a JSON line to experiments/perf/<arch>__<shape>.jsonl —
the hypothesis -> change -> before/after log lives in EXPERIMENTS.md.
"""

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402

import jax          # noqa: E402

from repro.core.bk import DPConfig                      # noqa: E402
from repro.launch.mesh import make_production_mesh      # noqa: E402
from repro.launch.steps import plan_cell                # noqa: E402
from repro.utils.hlo import analyze_hlo                 # noqa: E402

PERF_DIR = os.path.join(os.path.dirname(__file__), "../experiments/perf")

# variant name -> kwargs for plan_cell
VARIANTS = {
    "baseline": {},
    # paper-faithful base BK (pure ghost norm) for contrast
    "bk-base": {"dp": DPConfig(mode="bk", clipping="automatic", sigma=1.0)},
    # streamed BK: GhostClip-style 2nd backprop (no stored ds), bounded memory
    "streamed": {"dp": DPConfig(mode="ghostclip", clipping="automatic",
                                sigma=1.0)},
    "nonprivate": {"dp": DPConfig(mode="nonprivate")},
    "micro8": {"microbatch": 8},
    "micro32": {"microbatch": 32},
    "micro64": {"microbatch": 64},
    "no-remat": {"cfg_patch": {"remat": False}},
    "attn-chunk-1024": {"cfg_patch": {"attn_chunk": 1024}},
    "attn-chunk-256": {"cfg_patch": {"attn_chunk": 256}},
    "seq-shard-attn": {"cfg_patch": {"seq_shard_attn": True}},
    "sp-only": {"cfg_patch": {"seq_parallel": True}},
    "seq-shard+sp": {"cfg_patch": {"seq_shard_attn": True,
                                   "seq_parallel": True}},
    "seq-shard+micro64": {"cfg_patch": {"seq_shard_attn": True},
                          "microbatch": 64},
    "seq-shard+micro128": {"cfg_patch": {"seq_shard_attn": True},
                           "microbatch": 128},
    "seq-shard+nonprivate": {"cfg_patch": {"seq_shard_attn": True},
                             "dp": DPConfig(mode="nonprivate")},
    "cap-1.0": {"cfg_patch": {"capacity_factor": 1.0}},
    "cap-2.0": {"cfg_patch": {"capacity_factor": 2.0}},
    "adamw": {"optimizer": "adamw"},
    "adafactor": {"optimizer": "adafactor"},
    "ssm-chunk-64": {"cfg_patch": {"ssm_chunk": 64}},
    "ssm-chunk-128": {"cfg_patch": {"ssm_chunk": 128}},
    # replicate rwkv head projections over 'model' (whole heads per shard,
    # no per-chunk resharding of the recurrence)
    "rwkv-repl-proj": {"rule_patch": {r"(^|/)(key|receptance|r|k|v|g|xz)/w$":
                                      ("data", None),
                                      r"(^|/)(o|value)/w$": (None, "data")}},
}

PEAK_FLOPS, HBM_BW, ICI_BW = 197e12, 819e9, 50e9
COLL_W = {"all-reduce": 2.0}


def run_variant(arch, shape, variant, multi_pod=False):
    kw = dict(VARIANTS[variant])
    rule_patch = kw.pop("rule_patch", None)
    # pin the flat DPConfig: plan_cell's dp=None now resolves the arch's
    # registered group-wise policy preset, which changes the book-keeping
    # program — perf series must stay comparable to recorded baselines
    if "dp" not in kw:
        from repro.core.bk import DPConfig
        kw["dp"] = DPConfig(mode="bk-mixopt", clipping="automatic",
                            sigma=1.0)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    if rule_patch:
        from repro.launch import sharding
        patched = list(rule_patch.items()) + [
            (p, t) for p, t in sharding.RULES if p not in rule_patch]
        from unittest import mock
        with mock.patch.object(sharding, "RULES", patched):
            plan = plan_cell(arch, shape, mesh, **kw)
    else:
        plan = plan_cell(arch, shape, mesh, **kw)
    compiled = plan.lower().compile()
    ma = compiled.memory_analysis()
    h = analyze_hlo(compiled.as_text())
    wire = sum(COLL_W.get(k, 1.0) * v
               for k, v in h["collectives"].items() if k != "total")
    rec = {
        "arch": arch, "shape": shape, "variant": variant,
        "note": plan.note,
        "compile_s": round(time.time() - t0, 1),
        "flops": h["flops"], "traffic_bytes": h["traffic_bytes"],
        "collective_bytes": wire,
        "compute_s": h["flops"] / PEAK_FLOPS,
        "memory_s": h["traffic_bytes"] / HBM_BW,
        "collective_s": wire / ICI_BW,
        "arg_gib": ma.argument_size_in_bytes / 2**30,
        "temp_gib": ma.temp_size_in_bytes / 2**30,
    }
    rec["bound"] = max(("compute", rec["compute_s"]),
                       ("memory", rec["memory_s"]),
                       ("collective", rec["collective_s"]),
                       key=lambda t: t[1])[0]
    rec["step_s_bound"] = max(rec["compute_s"], rec["memory_s"],
                              rec["collective_s"])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline",
                    choices=sorted(VARIANTS))
    ap.add_argument("--multipod", action="store_true")
    args = ap.parse_args()
    rec = run_variant(args.arch, args.shape, args.variant, args.multipod)
    os.makedirs(PERF_DIR, exist_ok=True)
    path = os.path.join(PERF_DIR, f"{args.arch}__{args.shape}.jsonl")
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
