"""Kernel bench: every fused Pallas kernel vs its pure-jnp reference.

    PYTHONPATH=src python -m benchmarks.kernel_bench [--fast]

For each cell, measures wall time (us_per_call) of both sides and three HBM
traffic numbers, then writes ``BENCH_kernels.json``:

  traffic_bytes_jnp         utils.hlo.analyze_hlo over the jit-compiled jnp
                            reference — charges the (B,T,T) Grams /
                            (B,T,p) weighted copies / (B,E,C,C) expert Grams
                            the einsum formulation materializes in HBM;
  traffic_bytes_kernel      the kernel's DMA model: sum over grid steps of
                            fetched block bytes + output bytes written once —
                            exactly what Mosaic moves on TPU, where the tile
                            intermediates live in VMEM only;
  traffic_bytes_kernel_hlo  analyze_hlo over the kernel as actually lowered
                            HERE — on CPU that is interpret mode, which
                            emulates every VMEM block in HBM, so this number
                            is an upper bound that structurally over-charges
                            the kernel (reported for transparency).

Block sizes come from kernels.dispatch — the same plans the engine uses. On
CPU, us_per_call is a correctness-path number, not a TPU projection; the
reduced traffic_bytes_kernel vs traffic_bytes_jnp is the tracked signal.
"""
from __future__ import annotations

import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import ghost
from repro.kernels import dispatch, ops
from repro.utils.hlo import analyze_hlo

F32 = jnp.float32


def _mk(shape, seed=0, dtype=F32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, F32).astype(dtype)


def _time_us(fn, *args, reps=5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _traffic(fn, *args) -> float:
    # args go through jit parameters (NOT closure) so XLA cannot
    # constant-fold the benchmarked computation away
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return analyze_hlo(txt)["traffic_bytes"]


def _cdiv(a, b):
    return -(-a // b)


def _dma_models(L, B, T, d, p, V, E, C, bt, bte, bd, bp, bv, mbd, mbp):
    """Per-cell TPU DMA traffic: grid steps x fetched block bytes + output
    bytes (each output tile is accumulated in VMEM and written once)."""
    f = 4  # f32 operand bytes (int32 ids likewise)
    nt = _cdiv(T, bt)
    tri = nt * (nt + 1) // 2
    nte = _cdiv(T, bte)
    trie = nte * (nte + 1) // 2
    nd, np_ = _cdiv(d, bd), _cdiv(p, bp)
    mnd, mnp = _cdiv(d, mbd), _cdiv(p, mbp)
    nv = _cdiv(V, bv)
    return {
        "ghost_norm_mm": B * L * tri * 2 * bt * (d + p) * f + B * f,
        "direct_norm_mm": B * L * nd * np_ * T * (bd + bp) * f + B * f,
        "clipped_grad_mm": (L * nd * np_ * B * (T * (bd + bp) + 1) * f
                            + L * d * p * f),
        "ghost_norm_emb": B * L * trie * 2 * bt * (1 + d) * f + B * f,
        "clipped_grad_emb": (L * nv * B * (T * (1 + d) + 1) * f
                             + L * V * d * f),
        "ghost_norm_moe": B * L * E * C * (d + p + 1) * f + B * f,
        "direct_norm_moe": (B * L * E * mnd * mnp * C * (mbd + mbp + 1) * f
                            + B * f),
        "clipped_grad_moe": (L * E * mnd * mnp * B * (C * (mbd + mbp + 1) + 1)
                             * f + L * E * d * p * f),
    }


def _cells(fast: bool):
    L, B, T, d, p = (2, 4, 128, 32, 32) if fast else (4, 8, 256, 64, 64)
    V = 256 if fast else 1024
    E, C = (4, 16) if fast else (8, 32)

    a, ds = _mk((L, B, T, d)), _mk((L, B, T, p), 1)
    Cw = jnp.abs(_mk((B,), 2)) + 0.1
    ids = jax.random.randint(jax.random.PRNGKey(3), (L, B, T), 0, V)
    de = _mk((L, B, T, d), 4)
    ma = _mk((L, B, E, C, d), 5)
    mm = (jax.random.uniform(jax.random.PRNGKey(6), (L, B, E, C)) > 0.3
          ).astype(F32)
    mds = _mk((L, B, E, C, p), 7)
    rec = {"a": ma, "mask": mm}

    # block sizes from the same analytic model dispatch uses for its plans
    bt = dispatch.block_t_ghost(T, d, p)
    bte = dispatch.block_t_ghost(T, d, d)
    bd, bp = dispatch.block_dp(T, d, p)
    bv = dispatch.block_v(T, d, V)
    mbd, mbp = dispatch.block_dp(C, d, p)
    dma = _dma_models(L, B, T, d, p, V, E, C, bt, bte, bd, bp, bv, mbd, mbp)
    # cell -> (kernel_fn, ref_fn, args): args flow through jit parameters
    return dma, {
        "ghost_norm_mm": (
            lambda a, ds: ops.ghost_norm_mm(a, ds, block_t=bt),
            lambda a, ds: ghost.sq_norm_mm_ghost(a, ds), (a, ds)),
        "direct_norm_mm": (
            lambda a, ds: ops.direct_norm_mm(a, ds, block_d=bd, block_p=bp),
            lambda a, ds: ghost.sq_norm_mm_direct(a, ds), (a, ds)),
        "clipped_grad_mm": (
            lambda a, c, ds: ops.clipped_grad_mm(a, c, ds, block_d=bd,
                                                 block_p=bp),
            lambda a, c, ds: ghost.weighted_grad_mm(a, c, ds, F32),
            (a, Cw, ds)),
        "ghost_norm_emb": (
            lambda i, g: ops.ghost_norm_emb(i, g, block_t=bte),
            lambda i, g: ghost.sq_norm_emb(i, g), (ids, de)),
        "clipped_grad_emb": (
            lambda i, c, g: ops.clipped_grad_emb(i, c, g, V, block_v=bv),
            lambda i, c, g: ghost.weighted_grad_emb(i, c, g, V, F32),
            (ids, Cw, de)),
        "ghost_norm_moe": (
            lambda r, g: ops.ghost_norm_moe(r, g),
            lambda r, g: ghost.sq_norm_moe_ghost(r, g), (rec, mds)),
        "direct_norm_moe": (
            lambda r, g: ops.direct_norm_moe(r, g, block_d=mbd, block_p=mbp),
            lambda r, g: ghost.sq_norm_moe_direct(r, g), (rec, mds)),
        "clipped_grad_moe": (
            lambda r, c, g: ops.clipped_grad_moe(r, c, g, block_d=mbd,
                                                 block_p=mbp),
            lambda r, c, g: ghost.weighted_grad_moe(r, c, g, F32),
            (rec, Cw, mds)),
    }


def main(fast: bool = False) -> dict:
    results = {}
    dma, cells = _cells(fast)
    print(f"{'cell':>18} {'kern us':>9} {'jnp us':>9} {'kern MB':>8} "
          f"{'k-hlo MB':>9} {'jnp MB':>8} {'saving x':>9}")
    for name, (kfn, rfn, args) in cells.items():
        cell = {
            "us_per_call_kernel": _time_us(kfn, *args),
            "us_per_call_jnp": _time_us(rfn, *args),
            "traffic_bytes_kernel": float(dma[name]),
            "traffic_bytes_kernel_hlo": _traffic(kfn, *args),
            "traffic_bytes_jnp": _traffic(rfn, *args),
        }
        cell["traffic_ratio"] = (cell["traffic_bytes_jnp"] /
                                 max(cell["traffic_bytes_kernel"], 1.0))
        results[name] = cell
        print(f"{name:>18} {cell['us_per_call_kernel']:>9.0f} "
              f"{cell['us_per_call_jnp']:>9.0f} "
              f"{cell['traffic_bytes_kernel'] / 2**20:>8.2f} "
              f"{cell['traffic_bytes_kernel_hlo'] / 2**20:>9.2f} "
              f"{cell['traffic_bytes_jnp'] / 2**20:>8.2f} "
              f"{cell['traffic_ratio']:>9.2f}")
    out = {"backend": jax.default_backend(),
           "interpret_mode": jax.default_backend() != "tpu",
           "fast": fast, "cells": results}
    with open("BENCH_kernels.json", "w") as f:
        json.dump(out, f, indent=2)
    print("wrote BENCH_kernels.json")
    return out


if __name__ == "__main__":
    main(fast="--fast" in sys.argv)
