"""Reproduce paper Table 8: whole-model time/space complexity of BK vs
non-DP / GhostClip / Opacus, B=100, for RoBERTa / ViT / BEiT / GPT2 at their
task sequence lengths — validating the faithful complexity claims
(e.g. GPT2-large T=100: non-DP 0.97x, GhostClip 1.65x, Opacus 1.30x of BK)."""
from __future__ import annotations

from benchmarks.complexity import MODELS, model_space, model_time, transformer_layers

B = 100
# (model, T) cells exactly as Table 8's rows
ROWS = [
    ("roberta-base", 256), ("roberta-large", 256),
    ("vit-base", 197), ("vit-large", 197), ("beit-large", 197),
    ("gpt2-small", 100), ("gpt2-medium", 100), ("gpt2-large", 100),
    ("gpt2-small", 1000), ("gpt2-medium", 1000), ("gpt2-large", 1000),
]
# paper-reported ratios vs BK (time; space) for spot validation
PAPER_TIME_RATIOS = {
    ("gpt2-large", 100): {"nonDP": 0.97, "GhostClip": 1.65, "Opacus": 1.30},
    ("roberta-large", 256): {"nonDP": 0.89, "GhostClip": 1.59, "Opacus": 1.18},
    ("gpt2-large", 1000): {"nonDP": 0.79, "GhostClip": 1.55, "Opacus": 1.04},
}


def rows():
    out = []
    for name, T in ROWS:
        nl, d, vocab, ff = MODELS[name]
        layers = transformer_layers(nl, d, T, vocab, d_ff=ff)
        bk_t = model_time(layers, B, "BK-MixOpt")
        bk_s = model_space(layers, B, "BK-MixOpt")
        rec = {"model": name, "T": T, "bk_time": bk_t, "bk_space": bk_s}
        for impl in ("nonDP", "GhostClip", "Opacus"):
            rec[f"time_ratio_{impl}"] = model_time(layers, B, impl) / bk_t
            rec[f"space_ratio_{impl}"] = model_space(layers, B, impl) / bk_s
        out.append(rec)
    return out


def validate(tol: float = 0.15):
    """Computed ratios within tol of the paper's Table 8 values."""
    errs = []
    for rec in rows():
        key = (rec["model"], rec["T"])
        for impl, want in PAPER_TIME_RATIOS.get(key, {}).items():
            got = rec[f"time_ratio_{impl}"]
            if abs(got - want) / want > tol:
                errs.append(f"{key} {impl}: got {got:.2f} want {want:.2f}")
    return errs


def main(emit=print):
    emit("# Table 8 reproduction (time ratios vs BK-MixOpt, B=100)")
    emit(f"{'model':15s} {'T':>5s} {'BK(1e12)':>9s} {'nonDP':>6s} "
         f"{'Ghost':>6s} {'Opacus':>6s}")
    for rec in rows():
        emit(f"{rec['model']:15s} {rec['T']:5d} {rec['bk_time']/1e12:9.1f} "
             f"{rec['time_ratio_nonDP']:6.2f} {rec['time_ratio_GhostClip']:6.2f} "
             f"{rec['time_ratio_Opacus']:6.2f}")
    errs = validate()
    emit(f"validation vs paper: {'OK' if not errs else errs}")
    return errs


if __name__ == "__main__":
    main()
