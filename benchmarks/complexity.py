"""The paper's complexity model (Tables 2, 3, 5): time/space per
generalized-linear layer for every DP implementation, plus whole-model
aggregation used by table8/table10 reproductions.

Layer = (T, d, p) with batch B; units are FLOPs-ish "time complexity" counts
and array elements for space, exactly as the paper counts them.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

Layer = Tuple[int, int, int]  # (T, d, p)


# -------------------------------------------------- per-layer time complexity
def t_nondp(B, T, d, p):
    return 6 * B * T * p * d


def t_opacus(B, T, d, p):
    return 8 * B * T * p * d


def t_fastgradclip(B, T, d, p):
    return 8 * B * T * p * d


def t_ghostclip(B, T, d, p):
    return 10 * B * T * p * d + 2 * B * T * T * (p + d)


def t_bk(B, T, d, p):
    return 6 * B * T * p * d + 2 * B * T * T * (p + d)


def t_mixghostclip(B, T, d, p):
    return 8 * B * T * p * d + min(2 * B * T * p * d, 2 * B * T * T * (p + d))


def t_bk_mixghostclip(B, T, d, p):
    return 6 * B * T * p * d + min(2 * B * T * p * d, 2 * B * T * T * (p + d))


def t_bk_mixopt(B, T, d, p):
    ghost = 2 * T * T < p * d
    return 6 * B * T * p * d + (2 * B * T * T * (p + d) if ghost else 2 * B * p * d)


# ------------------------------------------------- per-layer space complexity
def s_nondp(B, T, d, p):
    return p * d + 3 * B * T * d + B * T * p


def s_extra_opacus(B, T, d, p):
    return B * p * d


s_extra_fastgradclip = s_extra_opacus


def s_extra_ghost(B, T, d, p):
    return 2 * B * T * T


def s_extra_mixed(B, T, d, p):
    return min(2 * B * T * T, B * p * d)


TIME = {"nonDP": t_nondp, "Opacus": t_opacus, "FastGradClip": t_fastgradclip,
        "GhostClip": t_ghostclip, "BK": t_bk, "MixGhostClip": t_mixghostclip,
        "BK-MixGhostClip": t_bk_mixghostclip, "BK-MixOpt": t_bk_mixopt}
SPACE_EXTRA = {"nonDP": lambda *a: 0, "Opacus": s_extra_opacus,
               "FastGradClip": s_extra_fastgradclip,
               "GhostClip": s_extra_ghost, "BK": s_extra_ghost,
               "MixGhostClip": s_extra_mixed, "BK-MixGhostClip": s_extra_mixed,
               "BK-MixOpt": s_extra_mixed}


def model_time(layers: List[Layer], B: int, impl: str) -> float:
    return float(sum(TIME[impl](B, T, d, p) for T, d, p in layers))


def model_space(layers: List[Layer], B: int, impl: str) -> float:
    base = sum(s_nondp(B, T, d, p) for T, d, p in layers)
    return float(base + sum(SPACE_EXTRA[impl](B, T, d, p) for T, d, p in layers))


def clip_norm_space(layers: List[Layer], B: int, impl: str) -> float:
    """Space of computing per-sample grad norms only (Tables 4/10)."""
    if impl == "ghost":
        return float(sum(2 * B * T * T for T, d, p in layers))
    if impl == "instantiate":
        return float(sum(B * p * d for T, d, p in layers))
    if impl == "mixed":
        return float(sum(min(2 * B * T * T, B * p * d) for T, d, p in layers))
    raise ValueError(impl)


# ----------------------------------------------------------- model descriptors
def transformer_layers(n_layers: int, d: int, T: int, vocab: int,
                       d_ff: int = 0, fused_qkv: bool = False) -> List[Layer]:
    """Generalized-linear layers of a GPT2/BERT-style block stack + embeddings
    (embedding ghost-norm T^2 term counted like a linear layer, following the
    paper's Appendix B treatment)."""
    ff = d_ff or 4 * d
    per_block: List[Layer] = (
        [(T, d, 3 * d)] if fused_qkv else [(T, d, d)] * 3)
    per_block += [(T, d, d), (T, d, ff), (T, ff, d)]
    layers = per_block * n_layers
    layers += [(T, vocab, d), (T, d, vocab)]   # embed + lm head
    return layers


MODELS = {
    # name: (n_layers, d_model, vocab, d_ff)
    "roberta-base": (12, 768, 50265, 3072),
    "roberta-large": (24, 1024, 50265, 4096),
    "vit-base": (12, 768, 1000, 3072),
    "vit-large": (24, 1024, 1000, 4096),
    "beit-large": (24, 1024, 1000, 4096),
    "gpt2-small": (12, 768, 50257, 3072),
    "gpt2-medium": (24, 1024, 50257, 4096),
    "gpt2-large": (36, 1280, 50257, 5120),
}


def conv_layer(h_out: int, in_c: int, out_c: int, k: int) -> Layer:
    return (h_out * h_out, in_c * k * k, out_c)


def resnet18_layers(img: int = 224) -> List[Layer]:
    s = img // 224  # scale the feature maps with input resolution
    m = lambda r: r * s
    L = [conv_layer(m(112), 3, 64, 7)]
    L += [conv_layer(m(56), 64, 64, 3)] * 4
    L += [conv_layer(m(28), 64, 128, 3)] + [conv_layer(m(28), 128, 128, 3)] * 3
    L += [conv_layer(m(14), 128, 256, 3)] + [conv_layer(m(14), 256, 256, 3)] * 3
    L += [conv_layer(m(7), 256, 512, 3)] + [conv_layer(m(7), 512, 512, 3)] * 3
    L += [(1, 512, 1000)]
    return L


def vgg11_layers(img: int = 224) -> List[Layer]:
    s = img // 224
    m = lambda r: r * s
    return [
        conv_layer(m(224), 3, 64, 3),
        conv_layer(m(112), 64, 128, 3),
        conv_layer(m(56), 128, 256, 3), conv_layer(m(56), 256, 256, 3),
        conv_layer(m(28), 256, 512, 3), conv_layer(m(28), 512, 512, 3),
        conv_layer(m(14), 512, 512, 3), conv_layer(m(14), 512, 512, 3),
        (1, 25088, 4096), (1, 4096, 4096), (1, 4096, 1000),
    ]


def vit_patch_layers(n_layers: int, d: int, img: int = 224,
                     patch: int = 16) -> List[Layer]:
    T = (img // patch) ** 2 + 1
    layers: List[Layer] = [((img // patch) ** 2, 3 * patch * patch, d)]
    # timm ViTs use a fused qkv linear — matches the paper's layer counting
    layers += transformer_layers(n_layers, d, T, 1000, fused_qkv=True)[:-2]
    layers += [(1, d, 1000)]
    return layers
