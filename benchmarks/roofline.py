"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three per-device terms per (arch x shape), single-pod 16x16 mesh, TPU v5e:

    compute    = HLO_FLOPs / 197e12         (bf16 peak per chip)
    memory     = HLO_bytes / 819e9          (HBM bandwidth)
    collective = wire_bytes / 50e9          (ICI per link; all-reduce ~2x its
                                             buffer, others ~1x)

cost_analysis() numbers are already per-partition (the SPMD module), so no
chip division is applied. MODEL_FLOPS uses 6*N*D (train) / 2*N*D (fwd-only),
N = active params, D = tokens — the utilization denominator that catches
remat / redundant compute.
"""
from __future__ import annotations

import json
import os

import jax

from repro.configs.base import SHAPES
from repro.configs.registry import build, get_config, list_archs
from repro.utils.tree import flatten

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256
COLL_WEIGHT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}

DRYRUN_DIR = os.path.join(os.path.dirname(__file__),
                          "../experiments/dryrun/singlepod_16x16")


def param_counts(arch: str):
    """(total, active) parameter counts via abstract init."""
    cfg = get_config(arch)
    model = build(cfg)
    params = jax.eval_shape(model.init,
                            jax.ShapeDtypeStruct((2,), "uint32"))
    total = active = 0
    for path, leaf in flatten(params).items():
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        if "/experts/" in path and cfg.n_experts:
            active += n * cfg.top_k / cfg.n_experts
        else:
            active += n
    return total, active


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    _, active = param_counts(arch)
    if shape.kind == "train":
        toks = shape.global_batch * (cfg.decoder_len if cfg.family == "encdec"
                                     else shape.seq_len)
        return 6.0 * active * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * (cfg.decoder_len if cfg.family == "encdec"
                                     else shape.seq_len)
        return 2.0 * active * toks
    return 2.0 * active * shape.global_batch  # decode: one token per sequence


def analyze(rec: dict) -> dict:
    # trip-aware totals from utils.hlo (XLA's cost_analysis counts scan
    # bodies once; see EXPERIMENTS.md §Dry-run methodology)
    flops = rec.get("hlo", rec["cost"]).get("flops", 0.0)
    byts = rec.get("hlo", {}).get("traffic_bytes",
                                  rec["cost"].get("bytes accessed", 0.0))
    wire = sum(COLL_WEIGHT.get(k, 1.0) * v
               for k, v in rec["collectives"].items() if k != "total")
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = wire / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / (flops * CHIPS) if flops else 0.0
    bound = max(t_c, t_m, t_x)
    frac = t_c / bound if bound else 0.0  # fraction of time on the MXU
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "dominant": dom, "model_flops": mf,
            "useful_flops_ratio": useful, "roofline_fraction": frac}


SUGGEST = {
    "compute": "compute-bound: reduce recompute (remat policy) or raise "
               "useful-flops ratio; MXU-align matmul dims",
    "memory": "memory-bound: fuse ghost-norm Grams (Pallas kernel removes "
              "2BT^2 HBM traffic), shrink book-kept taps via microbatch, "
              "chunk the lm-head loss",
    "collective": "collective-bound: reshard to cut all-gathers (FSDP "
                  "prefetch under scan), overlap via latency-hiding "
                  "scheduler, 8-bit pod-axis compression",
}


def load_cells(dryrun_dir: str = DRYRUN_DIR):
    cells = []
    if not os.path.isdir(dryrun_dir):
        return cells
    for fn in sorted(os.listdir(dryrun_dir)):
        if fn.endswith(".json"):
            with open(os.path.join(dryrun_dir, fn)) as f:
                cells.append(json.load(f))
    return cells


def main(emit=print, dryrun_dir: str = DRYRUN_DIR):
    cells = load_cells(dryrun_dir)
    if not cells:
        emit("roofline: no dry-run artifacts yet "
             "(run python -m repro.launch.dryrun --all)")
        return []
    emit("# Roofline (per-device seconds, single-pod 16x16 v5e)")
    emit(f"{'arch':22s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
         f"{'collect':>9s} {'dominant':>10s} {'useful':>7s} {'roofl%':>7s}")
    out = []
    for rec in cells:
        if rec["status"] == "skip":
            emit(f"{rec['arch']:22s} {rec['shape']:12s} {'skip':>9s} "
                 f"— {rec['reason'][:60]}")
            continue
        if rec["status"] != "ok":
            emit(f"{rec['arch']:22s} {rec['shape']:12s} {'ERROR':>9s}")
            continue
        a = analyze(rec)
        out.append({**rec, **a})
        emit(f"{rec['arch']:22s} {rec['shape']:12s} {a['compute_s']:9.4f} "
             f"{a['memory_s']:9.4f} {a['collective_s']:9.4f} "
             f"{a['dominant']:>10s} {a['useful_flops_ratio']:7.2f} "
             f"{100 * a['roofline_fraction']:6.1f}%")
    return out


if __name__ == "__main__":
    main()
