"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Emits ``name,us_per_call,derived`` CSV lines plus validation verdicts.
"""
from __future__ import annotations

import sys


def main() -> None:
    fast = "--fast" in sys.argv
    print("== Table 2/5/8: complexity model vs paper ==")
    from benchmarks import table8
    errs8 = table8.main()

    print("\n== Table 4/10: mixed ghost norm space savings ==")
    from benchmarks import table10
    errs10 = table10.main()

    print("\n== Figure 2: MLP speed/memory (measured) ==")
    from benchmarks import fig2_mlp
    fig2_mlp.main()

    print("\n== Table 9: throughput (measured, reduced GPT2) ==")
    from benchmarks import throughput
    throughput.main()

    print("\n== Roofline (from dry-run artifacts) ==")
    from benchmarks import roofline
    roofline.main()

    if errs8 or errs10:
        print(f"VALIDATION FAILURES: {errs8 + errs10}")
        raise SystemExit(1)
    print("\nall benchmark validations OK")


if __name__ == "__main__":
    main()
