"""Reproduce paper Figure 2 / Figure 9 (measured): MLP speed and memory for
every DP implementation. Wall-time measured on this host; memory from the
compiled module's buffer assignment (argument+temp bytes), which is the
hardware-independent analogue of the paper's GPU memory axis."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.bk import DPConfig
from repro.core.engine import ALL_MODES, make_grad_fn
from repro.models.mlp import MLP, MLPConfig

CONFIGS = {
    "deep": MLPConfig(d_in=128, width=256, depth=20, n_classes=10),
    "shallow": MLPConfig(d_in=128, width=256, depth=6, n_classes=10),
    "wide": MLPConfig(d_in=128, width=1024, depth=6, n_classes=10),
}
B = 64
MODES = ["nonprivate", "opacus", "fastgradclip", "ghostclip", "bk",
         "bk-mixghost", "bk-mixopt"]  # tfprivacy omitted: B sequential bwds


def bench_one(cfg: MLPConfig, mode: str, iters: int = 5):
    model = MLP(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (B, cfg.d_in)),
             "y": jax.random.randint(jax.random.PRNGKey(2), (B,), 0,
                                     cfg.n_classes)}
    fn = jax.jit(make_grad_fn(model.apply, DPConfig(mode=mode, sigma=0.5)))
    lowered = fn.lower(params, batch, jax.random.PRNGKey(3))
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    mem = ma.argument_size_in_bytes + ma.temp_size_in_bytes
    out = fn(params, batch, jax.random.PRNGKey(3))
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(params, batch, jax.random.PRNGKey(3))
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / iters * 1e6
    return us, mem


def main(emit=print):
    emit("# Fig 2 (measured): MLP grad step, us/call and compiled bytes")
    results = {}
    for cname, cfg in CONFIGS.items():
        for mode in MODES:
            us, mem = bench_one(cfg, mode)
            results[(cname, mode)] = (us, mem)
            emit(f"fig2_{cname}_{mode},{us:.0f},mem_bytes={mem}")
    # paper's qualitative claims, checked quantitatively:
    for cname in CONFIGS:
        bk_t, bk_m = results[(cname, "bk")]
        gc_t, gc_m = results[(cname, "ghostclip")]
        op_t, op_m = results[(cname, "opacus")]
        emit(f"check_{cname}: BK/GhostClip time={bk_t / gc_t:.2f} (<1 wanted), "
             f"BK/Opacus mem={bk_m / op_m:.2f} (<1 wanted)")
    return results


if __name__ == "__main__":
    main()
