"""Table 9-style measured throughput: a reduced GPT2-family transformer,
tokens/sec for each DP implementation on this host. Relative ordering
(nonDP > BK > GhostClip > Opacus-ish) is the paper's claim; absolute numbers
are CPU-host artifacts."""
from __future__ import annotations

import time

import jax

from repro.configs.base import ModelConfig
from repro.core.bk import DPConfig
from repro.core.engine import make_grad_fn
from repro.data.synthetic import make_batch
from repro.models.transformer import TransformerLM

B, T = 8, 64
MODES = ["nonprivate", "bk", "bk-mixopt", "ghostclip", "opacus", "fastgradclip"]


def tiny_gpt2() -> ModelConfig:
    return ModelConfig(name="tiny-gpt2", family="dense", n_layers=4,
                       d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
                       d_ff=512, vocab=512, norm="layernorm", act="gelu",
                       max_t=T)


def main(emit=print):
    emit("# Table 9 (measured, reduced GPT2): tokens/sec per implementation")
    cfg = tiny_gpt2()
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, B, T, seed=1)
    out = {}
    for mode in MODES:
        fn = jax.jit(make_grad_fn(model.apply, DPConfig(mode=mode, sigma=0.5)))
        r = fn(params, batch, jax.random.PRNGKey(2))
        jax.block_until_ready(r)
        iters = 3
        t0 = time.perf_counter()
        for _ in range(iters):
            r = fn(params, batch, jax.random.PRNGKey(2))
        jax.block_until_ready(r)
        dt = (time.perf_counter() - t0) / iters
        tps = B * T / dt
        out[mode] = tps
        emit(f"throughput_{mode},{dt * 1e6:.0f},tokens_per_s={tps:.0f}")
    emit(f"check: BK speedup over GhostClip = {out['bk'] / out['ghostclip']:.2f}x"
         f" (paper: 1.3-1.4x on A100)")
    return out


if __name__ == "__main__":
    main()
