"""Reproduce paper Tables 4/10: space complexity of computing per-sample
gradient norms — ghost vs instantiation vs mixed (the layerwise decision),
on ResNet18 / VGG11 / ViT at ImageNet resolution. Validates e.g. ResNet18:
ghost 399M, instantiation 11.5M, mixed 1.0M (399x / 11.5x savings)."""
from __future__ import annotations

from benchmarks.complexity import (clip_norm_space, resnet18_layers,
                                   vgg11_layers, vit_patch_layers)

# paper Table 10 values (B=1, elements)
PAPER = {
    "resnet18": {"ghost": 399e6, "instantiate": 11.5e6, "mixed": 1.0e6},
    "vit-base": {"ghost": 3.8e6, "instantiate": 86.3e6, "mixed": 3.8e6},
}


def rows():
    models = {
        "resnet18": resnet18_layers(224),
        "resnet18@512": resnet18_layers(448),   # higher-res regime (Fig. 7)
        "vgg11": vgg11_layers(224),
        "vit-base": vit_patch_layers(12, 768),
        "vit-large": vit_patch_layers(24, 1024),
    }
    out = []
    for name, layers in models.items():
        rec = {"model": name}
        for impl in ("ghost", "instantiate", "mixed"):
            rec[impl] = clip_norm_space(layers, 1, impl)
        rec["saving_vs_ghost"] = rec["ghost"] / rec["mixed"]
        rec["saving_vs_inst"] = rec["instantiate"] / rec["mixed"]
        out.append(rec)
    return out


def validate(tol=0.3):
    errs = []
    for rec in rows():
        want = PAPER.get(rec["model"])
        if not want:
            continue
        for impl, w in want.items():
            if abs(rec[impl] - w) / w > tol:
                errs.append(f"{rec['model']}/{impl}: got {rec[impl]:.3g} "
                            f"want {w:.3g}")
    return errs


def main(emit=print):
    emit("# Table 10 reproduction: per-sample-grad-norm space (B=1, elements)")
    emit(f"{'model':14s} {'ghost':>10s} {'instant':>10s} {'mixed':>10s} "
         f"{'save/ghost':>10s} {'save/inst':>10s}")
    for r in rows():
        emit(f"{r['model']:14s} {r['ghost']:10.3g} {r['instantiate']:10.3g} "
             f"{r['mixed']:10.3g} {r['saving_vs_ghost']:10.1f} "
             f"{r['saving_vs_inst']:10.1f}")
    errs = validate()
    emit(f"validation vs paper: {'OK' if not errs else errs}")
    return errs


if __name__ == "__main__":
    main()
