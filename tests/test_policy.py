"""PrivacyPolicy semantics: group partition, group-wise clipping vs a vmap
per-sample reference, frozen groups (zero grads, no taps), per-group
sensitivity composition, pluggable noise (tree aggregation), and the
DPConfig -> single-flat-group shim."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bk import DPConfig
from repro.core.engine import PrivacyEngine, make_grad_fn
from repro.core.noise import TreeAggregationMechanism, get_mechanism
from repro.core.policy import (ParamGroup, PrivacyPolicy, as_policy,
                               resolve_policy)
from repro.models.mlp import MLP, MLPConfig
from repro.utils.tree import flatten

B = 8


def _setup(bias=True):
    model = MLP(MLPConfig(d_in=12, width=16, depth=3, n_classes=5, bias=bias))
    params = model.init(jax.random.PRNGKey(0))
    batch = {
        "x": jax.random.normal(jax.random.PRNGKey(1), (B, 12)),
        "y": jax.random.randint(jax.random.PRNGKey(2), (B,), 0, 5),
    }
    return model, params, batch


TWO_GROUPS = (
    ParamGroup("first", r"l0/.*", clipping="abadi", R=0.7, scope="group"),
    ParamGroup("rest", ".*", clipping="abadi", R=1.3, scope="group"),
)


def _vmap_reference(model, params, batch, policy):
    """Per-sample grads by vmap(grad) + hand-rolled group-wise clipping —
    the ground truth every implementation must match."""
    res = resolve_policy(policy, flatten(params))
    gfn = jax.grad(lambda p, s: model.apply(
        p, jax.tree_util.tree_map(lambda x: x[None], s),
        __import__("repro.core.tape", fromlist=["Tape"]).Tape(None))[0])
    per_g = flatten(jax.vmap(gfn, in_axes=(None, 0))(params, batch))
    norms, C = {}, {}
    for unit in res.units:
        sq = sum(jnp.sum(jnp.square(per_g[p].reshape(B, -1)), axis=1)
                 for p in unit.paths)
        norms[unit.name] = jnp.sqrt(sq)
        C[unit.name] = unit.clip_fn()(norms[unit.name])
    out = {}
    for p, g in per_g.items():
        if p in res.frozen:
            out[p] = jnp.zeros(g.shape[1:], g.dtype)
        else:
            unit = res.units[res.unit_of[p]]
            out[p] = jnp.einsum("b...,b->...", g, C[unit.name]) / B
    return out, norms


# ----------------------------------------------------------------- partition
def test_partition_first_match_wins():
    policy = PrivacyPolicy(groups=TWO_GROUPS)
    _, params, _ = _setup()
    res = resolve_policy(policy, flatten(params))
    assert res.group_of["l0/w"].name == "first"
    assert res.group_of["l1/w"].name == "rest"
    # a true partition: every param in exactly one unit
    seen = [p for u in res.units for p in u.paths]
    assert sorted(seen) == sorted(flatten(params))
    assert len(seen) == len(set(seen))


def test_unmatched_param_raises():
    policy = PrivacyPolicy(groups=(
        ParamGroup("only-l0", r"l0/.*", R=1.0, scope="group"),))
    _, params, _ = _setup()
    with pytest.raises(ValueError, match="matched no policy group"):
        resolve_policy(policy, flatten(params))


def test_flat_groups_must_agree_on_R():
    policy = PrivacyPolicy(groups=(
        ParamGroup("a", r"l0/.*", R=1.0, scope="flat"),
        ParamGroup("b", ".*", R=2.0, scope="flat"),
    ))
    _, params, _ = _setup()
    with pytest.raises(ValueError, match="flat-scope groups"):
        resolve_policy(policy, flatten(params))


def test_bad_scope_and_method_raise():
    with pytest.raises(ValueError, match="scope"):
        ParamGroup("x", ".*", scope="tensor")
    with pytest.raises(ValueError, match="method"):
        ParamGroup("x", ".*", method="magic")


# ---------------------------------------------------- group-wise correctness
@pytest.mark.parametrize("mode", ["bk", "bk-mixopt", "opacus"])
def test_groupwise_matches_vmap_reference(mode):
    model, params, batch = _setup()
    policy = PrivacyPolicy(groups=TWO_GROUPS, mode=mode, sigma=0.0)
    ref, ref_norms = _vmap_reference(model, params, batch, policy)
    got, aux = jax.jit(make_grad_fn(model.apply, policy))(
        params, batch, jax.random.PRNGKey(7))
    for name, n in ref_norms.items():
        np.testing.assert_allclose(aux["group_norms"][name], n,
                                   rtol=1e-5, atol=1e-7, err_msg=name)
    for p, g in sorted(flatten(got).items()):
        np.testing.assert_allclose(g, ref[p], rtol=1e-4, atol=1e-6,
                                   err_msg=f"{mode}:{p}")


@pytest.mark.parametrize("mode", ["tfprivacy", "fastgradclip", "ghostclip",
                                  "bk-mixghost"])
def test_all_other_modes_honor_policy(mode):
    model, params, batch = _setup()
    policy = PrivacyPolicy(groups=TWO_GROUPS, mode=mode, sigma=0.0)
    ref, _ = _vmap_reference(model, params, batch, policy)
    got, _ = jax.jit(make_grad_fn(model.apply, policy))(
        params, batch, jax.random.PRNGKey(7))
    for p, g in sorted(flatten(got).items()):
        np.testing.assert_allclose(g, ref[p], rtol=1e-4, atol=1e-6,
                                   err_msg=f"{mode}:{p}")


def test_method_override_same_norms():
    """Per-group ghost-vs-direct override changes the plan, not the math."""
    model, params, batch = _setup()
    base = PrivacyPolicy(groups=(
        ParamGroup("a", r"l0/.*", R=1.0, scope="group", method="direct"),
        ParamGroup("b", ".*", R=1.0, scope="group", method="ghost"),
    ), mode="bk-mixghost")
    swapped = PrivacyPolicy(groups=(
        ParamGroup("a", r"l0/.*", R=1.0, scope="group", method="ghost"),
        ParamGroup("b", ".*", R=1.0, scope="group", method="direct"),
    ), mode="bk-mixghost")
    g1, a1 = make_grad_fn(model.apply, base)(params, batch,
                                             jax.random.PRNGKey(3))
    g2, a2 = make_grad_fn(model.apply, swapped)(params, batch,
                                                jax.random.PRNGKey(3))
    for name in ("a", "b"):
        np.testing.assert_allclose(a1["group_norms"][name],
                                   a2["group_norms"][name],
                                   rtol=1e-5, atol=1e-7)


# -------------------------------------------------------------- frozen groups
@pytest.mark.parametrize("mode", ["bk", "bk-mixopt", "opacus", "ghostclip"])
def test_frozen_group_zero_grads(mode):
    model, params, batch = _setup()
    policy = PrivacyPolicy(groups=(
        ParamGroup("frozen", r"l0/.*", trainable=False),
        ParamGroup("rest", ".*", R=1.0),
    ), mode=mode, sigma=0.5)
    got, _ = make_grad_fn(model.apply, policy)(params, batch,
                                               jax.random.PRNGKey(5))
    flat = flatten(got)
    for p, g in flat.items():
        if p.startswith("l0/"):
            assert np.all(np.asarray(g) == 0), p  # not even noise
        else:
            assert np.any(np.asarray(g) != 0), p


def test_frozen_group_emits_no_tap():
    model, params, batch = _setup()
    policy = PrivacyPolicy(groups=(
        ParamGroup("frozen", r"l0/.*", trainable=False),
        ParamGroup("rest", ".*", R=1.0),
    ), mode="bk")
    engine = PrivacyEngine(model.apply, policy)
    report = engine.kernel_report(params, batch)
    assert "l0#mm" not in report
    assert {"l1#mm", "l2#mm", "head#mm"} <= set(report)


def test_frozen_trainable_agreement():
    """Trainable-group grads are unchanged by freezing a disjoint group
    (clipping-only; the frozen params leave the norm pool)."""
    model, params, batch = _setup()
    frozen = PrivacyPolicy(groups=(
        ParamGroup("frozen", r"l0/.*", trainable=False),
        ParamGroup("rest", ".*", R=1.0, scope="group"),
    ), mode="bk")
    ref, _ = _vmap_reference(model, params, batch, frozen)
    got, _ = make_grad_fn(model.apply, frozen)(params, batch,
                                               jax.random.PRNGKey(5))
    for p, g in sorted(flatten(got).items()):
        np.testing.assert_allclose(g, ref[p], rtol=1e-4, atol=1e-6, err_msg=p)


# ---------------------------------------------------------------- sensitivity
def test_sensitivity_composition():
    _, params, _ = _setup()
    policy = PrivacyPolicy(groups=(
        ParamGroup("a", r"l0/.*", R=3.0, scope="group"),
        ParamGroup("b", ".*", R=4.0, scope="group"),
    ))
    res = resolve_policy(policy, flatten(params))
    assert res.sensitivity == pytest.approx(5.0)
    # empty groups contribute nothing
    policy2 = PrivacyPolicy(groups=(
        ParamGroup("ghost-town", r"does/not/exist", R=100.0, scope="group"),
        ParamGroup("b", ".*", R=4.0, scope="group"),
    ))
    assert resolve_policy(policy2,
                          flatten(params)).sensitivity == pytest.approx(4.0)


def test_noise_scales_with_sensitivity():
    """sigma * sqrt(sum R_g^2) reaches every leaf regardless of its group."""
    _, params, _ = _setup()
    flat = {p: jnp.zeros(100_000, jnp.float32) for p in ("l0/w", "l1/w")}
    policy = PrivacyPolicy(groups=(
        ParamGroup("a", r"l0/.*", R=3.0, scope="group"),
        ParamGroup("b", ".*", R=4.0, scope="group"),
    ), sigma=1.0)
    res = resolve_policy(policy, flat)
    out = policy.mechanism().add(flat, jax.random.PRNGKey(0), policy.sigma,
                                 res.sensitivity, 1.0)
    for p, g in out.items():
        assert np.std(np.asarray(g)) == pytest.approx(5.0, rel=0.05), p


# ----------------------------------------------------------- noise mechanisms
def test_tree_mechanism_shape_and_variance():
    mech = TreeAggregationMechanism(seed=0)
    shape = (200_000,)
    for t, pop in [(1, 1), (2, 1), (3, 2), (6, 2), (7, 3), (8, 1)]:
        n = mech.prefix_noise("w", shape, t)
        assert n.shape == shape and n.dtype == jnp.float32
        assert np.var(np.asarray(n)) == pytest.approx(pop, rel=0.05), t


def test_tree_mechanism_telescopes():
    """Per-step increments sum EXACTLY to the prefix-tree noise — the
    optimizer's running gradient sum carries N(t), not t independent draws."""
    mech = TreeAggregationMechanism(seed=3)
    flat = {"a/w": jnp.zeros((4, 5)), "b": jnp.zeros((7,))}
    total = {p: np.zeros(g.shape, np.float32) for p, g in flat.items()}
    T = 11
    for step in range(T):
        out = mech.add(flat, jax.random.PRNGKey(step), sigma=1.0,
                       sensitivity=1.0, denom=1.0, step=step)
        for p in flat:
            total[p] += np.asarray(out[p])
    for p, g in flat.items():
        np.testing.assert_allclose(total[p],
                                   np.asarray(mech.prefix_noise(p, g.shape, T)),
                                   rtol=1e-4, atol=1e-5)


def test_tree_mechanism_via_engine():
    model, params, batch = _setup()
    policy = PrivacyPolicy(groups=(ParamGroup("all", ".*", R=1.0),),
                           mode="bk", sigma=0.5, noise="tree")
    fn = jax.jit(make_grad_fn(model.apply, policy))
    g0, _ = fn(params, batch, jax.random.PRNGKey(0), 0)
    g1, _ = fn(params, batch, jax.random.PRNGKey(0), 1)
    # different steps -> different noise increments
    assert not np.allclose(np.asarray(flatten(g0)["l1/w"]),
                           np.asarray(flatten(g1)["l1/w"]))


def test_tree_mechanism_requires_step():
    """Omitting the step would silently re-add the same draw every call —
    it must raise instead."""
    model, params, batch = _setup()
    policy = PrivacyPolicy(groups=(ParamGroup("all", ".*", R=1.0),),
                           mode="bk", sigma=0.5, noise="tree")
    fn = make_grad_fn(model.apply, policy)
    with pytest.raises(ValueError, match="stateful"):
        fn(params, batch, jax.random.PRNGKey(0))


def test_tree_depth_threads_through_policy():
    policy = PrivacyPolicy(groups=(ParamGroup("all", ".*", R=1.0),),
                           noise="tree", noise_depth=7)
    assert policy.mechanism().depth == 7


def test_unknown_mechanism_raises():
    with pytest.raises(ValueError, match="unknown noise mechanism"):
        get_mechanism("laplace")


# ------------------------------------------------------------------- the shim
def test_dpconfig_shim_lowering():
    cfg = DPConfig(mode="bk-mixopt", clipping="abadi", R=2.0, sigma=0.3,
                   use_kernels=False)
    policy = as_policy(cfg)
    assert len(policy.groups) == 1
    g = policy.groups[0]
    assert (g.scope, g.clipping, g.R) == ("flat", "abadi", 2.0)
    assert (policy.mode, policy.sigma, policy.noise,
            policy.use_kernels) == ("bk-mixopt", 0.3, "gaussian", False)


def test_dpconfig_and_lowered_policy_agree():
    model, params, batch = _setup()
    cfg = DPConfig(mode="bk", clipping="automatic", R=1.0, sigma=0.4)
    g1, a1 = make_grad_fn(model.apply, cfg)(params, batch,
                                            jax.random.PRNGKey(7))
    g2, a2 = make_grad_fn(model.apply, as_policy(cfg))(params, batch,
                                                       jax.random.PRNGKey(7))
    np.testing.assert_array_equal(a1["per_sample_norms"],
                                  a2["per_sample_norms"])
    assert "clip_factors" in a1  # single-unit aux keeps the old contract
    for (p, x), (_, y) in zip(sorted(flatten(g1).items()),
                              sorted(flatten(g2).items())):
        np.testing.assert_array_equal(x, y, err_msg=p)


# ------------------------------------------------------------------- presets
def test_registered_policy_presets_resolve():
    from repro.configs.registry import get_policy, list_policies
    from repro.configs.registry import build, smoke_config

    assert "deepseek-moe-16b" in list_policies()
    cfg = smoke_config("deepseek-moe-16b").with_(dtype="float32",
                                                 param_dtype="float32")
    params = build(cfg).init(jax.random.PRNGKey(0))
    policy = get_policy("deepseek-moe-16b", sigma=0.1)
    res = resolve_policy(policy, flatten(params))
    assert res.group_of["blocks/mlp/experts/up/w"].name == "experts"
    assert res.group_of["blocks/mlp/router/w"].name == "router"
    assert res.group_of["blocks/attn/qkv/w"].name == "dense"
    assert policy.sigma == 0.1


def test_microbatch_accumulation_with_policy():
    from repro.optim.accumulate import accumulated_private_grad
    model, params, batch = _setup()
    policy = PrivacyPolicy(groups=TWO_GROUPS, mode="bk", sigma=0.2)
    full, _ = jax.jit(lambda p, b, r: accumulated_private_grad(
        model.apply, p, b, r, policy, 0))(params, batch, jax.random.PRNGKey(1))
    micro, _ = jax.jit(lambda p, b, r: accumulated_private_grad(
        model.apply, p, b, r, policy, 4))(params, batch, jax.random.PRNGKey(1))
    for (p, x), (_, y) in zip(sorted(flatten(full).items()),
                              sorted(flatten(micro).items())):
        np.testing.assert_allclose(x, y, rtol=1e-4, atol=1e-6, err_msg=p)


# ---------------------------------------------------------- autotune warmup
def test_autotune_warmup_pins_blocks(monkeypatch):
    from repro.kernels import dispatch
    from repro.launch.train import autotune_warmup
    monkeypatch.setenv("REPRO_KERNELS", "1")  # tiny shapes: force kernel impl
    dispatch.clear_cache()
    try:
        model, params, batch = _setup()
        cfg = DPConfig(mode="bk", use_kernels=True)
        n = autotune_warmup(model.apply, params, batch, cfg, log=lambda *_: None)
        assert n > 0
        # the pinned plan survives for identical shapes and still computes
        # the right thing
        got, aux = make_grad_fn(model.apply, cfg)(params, batch,
                                                  jax.random.PRNGKey(7))
        ref, raux = make_grad_fn(
            model.apply, dataclasses.replace(cfg, use_kernels=False))(
                params, batch, jax.random.PRNGKey(7))
        np.testing.assert_allclose(aux["per_sample_norms"],
                                   raux["per_sample_norms"],
                                   rtol=1e-4, atol=1e-6)
    finally:
        dispatch.clear_cache()
