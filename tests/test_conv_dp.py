"""Conv ghost norm (paper Sec. 3 / Bu et al. 2022a): a small CNN trained
with BK equals Opacus exactly, and the layerwise hybrid decision picks the
right branch in both feature-dimension regimes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ghost
from repro.core.bk import DPConfig
from repro.core.engine import make_grad_fn
from repro.models import layers as L
from repro.utils.tree import flatten

B, H, W, C, NC = 4, 8, 8, 3, 5


class TinyCNN:
    """conv3x3 -> relu -> conv3x3(s2) -> relu -> gap -> linear."""

    def init(self, rng):
        ks = jax.random.split(rng, 3)
        return {
            "c1": L.conv2d_init(ks[0], 3, 3, C, 8, jnp.float32, bias=True),
            "c2": L.conv2d_init(ks[1], 3, 3, 8, 16, jnp.float32),
            "head": L.linear_init(ks[2], 16, NC, jnp.float32, bias=True),
        }

    def apply(self, params, batch, tape):
        x = batch["x"]
        x = jax.nn.relu(L.conv2d(tape, "c1", params["c1"], x, 3, 3))
        x = jax.nn.relu(L.conv2d(tape, "c2", params["c2"], x, 3, 3, stride=2))
        x = jnp.mean(x, axis=(1, 2))[:, None, :]          # GAP -> (B,1,16)
        logits = L.linear(tape, "head", params["head"], x)[:, 0]
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
        return logz - gold


def _setup():
    model = TinyCNN()
    params = model.init(jax.random.PRNGKey(0))
    batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (B, H, W, C)),
             "y": jax.random.randint(jax.random.PRNGKey(2), (B,), 0, NC)}
    return model, params, batch


@pytest.mark.parametrize("mode", ["bk", "bk-mixopt", "bk-mixghost",
                                  "ghostclip"])
def test_cnn_bk_equals_opacus(mode):
    model, params, batch = _setup()
    ref, ra = make_grad_fn(model.apply, DPConfig(mode="opacus"))(
        params, batch, jax.random.PRNGKey(3))
    got, ga = make_grad_fn(model.apply, DPConfig(mode=mode))(
        params, batch, jax.random.PRNGKey(3))
    np.testing.assert_allclose(ga["per_sample_norms"], ra["per_sample_norms"],
                               rtol=1e-5, atol=1e-6)
    for (p, g), (_, r) in zip(sorted(flatten(got).items()),
                              sorted(flatten(ref).items())):
        np.testing.assert_allclose(g, r, rtol=1e-4, atol=1e-6, err_msg=p)


def test_conv_hybrid_decision_regimes():
    """Early conv (large T, tiny pd) -> instantiation; late/fc -> ghost —
    the paper's Table 4 layerwise pattern."""
    # conv1 of ResNet18 at 224x224: T=112^2, d=3*49, p=64
    assert not ghost.prefer_ghost(T=112 * 112, d=147, p=64)
    # fc: T=1
    assert ghost.prefer_ghost(T=1, d=512, p=1000)


def test_conv_record_shapes():
    from repro.core.tape import Tape
    model, params, batch = _setup()
    tape = Tape(None)
    model.apply(params, batch, tape)
    a1 = tape.acts["c1#mm"]
    assert a1.shape == (B, H * W, 3 * 3 * C)      # T = H'*W', d = kh*kw*C
    a2 = tape.acts["c2#mm"]
    assert a2.shape == (B, (H // 2) * (W // 2), 3 * 3 * 8)


def test_cnn_dp_training_reduces_loss():
    model, params, batch = _setup()
    fn = jax.jit(make_grad_fn(model.apply,
                              DPConfig(mode="bk-mixopt", sigma=0.1)))
    from repro.core.tape import Tape

    def loss(p):
        return jnp.mean(model.apply(p, batch, Tape(None)))

    l0 = float(loss(params))
    for step in range(15):
        grads, _ = fn(params, batch, jax.random.fold_in(jax.random.PRNGKey(5),
                                                        step))
        params = jax.tree_util.tree_map(lambda p, g: p - 5e-2 * g, params,
                                        grads)
    assert float(loss(params)) < l0
