"""runtime.compression edge cases: the scale floor on all-zero tensors,
unbiasedness of the stochastic rounding (hypothesis), and the quantized
tree all-reduce over a mixed-dtype pytree (ISSUE 5 satellite)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.compression import (compressed_allreduce_mean,
                                       compressed_tree_allreduce_mean,
                                       dequantize, quantize)

jax.config.update("jax_enable_x64", False)


def test_all_zero_tensor_hits_scale_floor():
    """quantize(0) must not divide by zero: the per-tensor scale floors at
    1e-12/127 and the round trip is exactly zero, no NaN/inf anywhere."""
    q, scale = quantize(jnp.zeros((5, 7)), jax.random.PRNGKey(0))
    assert float(scale) > 0.0
    np.testing.assert_array_equal(np.asarray(q), 0)
    out = np.asarray(dequantize(q, scale))
    np.testing.assert_array_equal(out, 0.0)
    assert np.isfinite(out).all()


def test_roundtrip_error_bounded_by_one_grid_step():
    x = jax.random.normal(jax.random.PRNGKey(1), (64,)) * 3.0
    q, scale = quantize(x, jax.random.PRNGKey(2))
    err = np.abs(np.asarray(dequantize(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) * (1 + 1e-6)


def test_tiny_magnitudes_stay_finite():
    """Values far below the floor quantize to zero, not to garbage."""
    x = jnp.full((8,), 1e-20)
    q, scale = quantize(x, jax.random.PRNGKey(0))
    assert np.isfinite(np.asarray(dequantize(q, scale))).all()


try:  # hypothesis is optional (guarded like tests/test_ghost_properties)
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _unbiased_body(val: float, seed: int):
    """E[dequantize(quantize(x))] == x: average the round trip over many
    independent rounding draws and check the mean against a 4-sigma bound
    of the rounding variance (each draw's error is within one grid step,
    so the mean's std is <= scale / (2*sqrt(n)))."""
    n = 400
    x = jnp.full((16,), val, jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(seed), n)

    def rt(k):
        q, s = quantize(x, k)
        return dequantize(q, s)

    outs = np.asarray(jax.vmap(rt)(keys))           # (n, 16)
    _, scale = quantize(x, keys[0])
    tol = 4.0 * float(scale) / (2.0 * np.sqrt(n * x.size)) + 1e-7
    assert abs(outs.mean() - val) <= tol, (outs.mean(), val, tol)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(val=st.floats(-10.0, 10.0, allow_nan=False),
           seed=st.integers(0, 2**16))
    def test_stochastic_rounding_unbiased(val, seed):
        _unbiased_body(val, seed)
else:
    @pytest.mark.parametrize("val,seed", [(0.37, 0), (-3.2, 1), (9.99, 2),
                                          (1e-3, 3), (-0.5, 4)])
    def test_stochastic_rounding_unbiased(val, seed):
        _unbiased_body(val, seed)


def test_tree_allreduce_mean_mixed_dtype_pytree():
    """compressed_tree_allreduce_mean over {f32, bf16, nested} leaves via a
    vmapped axis: each leaf comes back in ITS dtype, equal to the true mean
    within one int8 grid step per shard."""
    n = 4
    rng = jax.random.PRNGKey(3)
    tree = {
        "w": jax.random.normal(rng, (n, 6, 5), jnp.float32),
        "nested": {"b": (jax.random.normal(jax.random.fold_in(rng, 1),
                                           (n, 7)) * 0.1).astype(jnp.bfloat16)},
    }

    def body(leaf_tree, r):
        return compressed_tree_allreduce_mean(leaf_tree, r, "pods")

    out = jax.vmap(body, axis_name="pods",
                   in_axes=(0, None))(tree, jax.random.PRNGKey(9))
    assert out["w"].dtype == jnp.float32
    assert out["nested"]["b"].dtype == jnp.bfloat16
    for path, leaf, got in (("w", tree["w"], out["w"]),
                            ("nested/b", tree["nested"]["b"],
                             out["nested"]["b"])):
        want = np.asarray(leaf, np.float32).mean(axis=0)
        scale = np.abs(np.asarray(leaf, np.float32)).max() / 127.0
        # every pod sees the same reduced mean, within quantization error
        # (bf16 leaves additionally pay the output cast)
        tol = scale + (0.01 if got.dtype == jnp.bfloat16 else 1e-6)
        for shard in range(n):
            np.testing.assert_allclose(
                np.asarray(got[shard], np.float32), want, atol=tol,
                err_msg=f"{path} shard {shard}")


def test_allreduce_mean_matches_uncompressed_within_grid():
    n = 8
    x = jax.random.normal(jax.random.PRNGKey(4), (n, 32))
    out = jax.vmap(lambda xi, r: compressed_allreduce_mean(xi, r, "ax"),
                   axis_name="ax", in_axes=(0, None))(x, jax.random.PRNGKey(5))
    want = np.asarray(x).mean(axis=0)
    scale = np.abs(np.asarray(x)).max() / 127.0
    np.testing.assert_allclose(np.asarray(out[0]), want, atol=scale)
