"""Dry-run plumbing on a small virtual mesh (subprocess: needs >1 device).

Exercises plan_cell -> lower -> compile for each model family and all three
step kinds with reduced configs, on a (2 data x 2 model [+2 pod]) mesh."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
    import jax, jax.numpy as jnp
    from unittest import mock
    from repro.configs.base import SHAPES, ShapeConfig
    from repro.configs import registry
    from repro.launch.steps import plan_cell
    from repro.utils.hlo import collective_bytes

    mesh = jax.make_mesh({mesh_shape}, {mesh_axes})
    # shrink the configs + shapes so CPU compiles in seconds
    small = registry.smoke_config("{arch}").with_(name="{arch}", remat=False,
                                                  attn_chunk=0)
    SHAPES["train_4k"] = ShapeConfig("train_4k", 16, 8, "train")
    SHAPES["prefill_32k"] = ShapeConfig("prefill_32k", 32, 4, "prefill")
    SHAPES["decode_32k"] = ShapeConfig("decode_32k", 32, 8, "decode")
    SHAPES["long_500k"] = ShapeConfig("long_500k", 64, 2, "decode")
    with mock.patch.object(registry, "get_config", lambda n: small), \\
         mock.patch("repro.launch.steps.get_config", lambda n: small), \\
         mock.patch.dict("repro.launch.steps.TRAIN_MICROBATCH",
                         {{"{arch}": 4}}):
        plan = plan_cell("{arch}", "{shape}", mesh)
        lowered = plan.lower()
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        cb = collective_bytes(compiled.as_text())
        assert ma.argument_size_in_bytes > 0
        print("OK", "{arch}", "{shape}", plan.kind,
              "coll=", cb.get("total", 0))
""")


def _run(arch, shape, ndev=4, mesh_shape="(2, 2)", mesh_axes='("data", "model")'):
    code = CODE.format(arch=arch, shape=shape, ndev=ndev,
                       mesh_shape=mesh_shape, mesh_axes=mesh_axes)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=560, cwd=ROOT)
    assert r.returncode == 0 and "OK" in r.stdout, (r.stdout[-1500:] +
                                                    r.stderr[-3000:])


FAMILY_REPS = ["qwen3-14b", "deepseek-moe-16b", "rwkv6-3b", "hymba-1.5b",
               "whisper-small", "internvl2-26b"]


@pytest.mark.parametrize("arch", FAMILY_REPS)
def test_train_cell_small_mesh(arch):
    _run(arch, "train_4k")


@pytest.mark.parametrize("arch", ["qwen3-14b", "whisper-small", "rwkv6-3b"])
def test_prefill_cell_small_mesh(arch):
    _run(arch, "prefill_32k")


@pytest.mark.parametrize("arch", ["qwen3-14b", "deepseek-moe-16b",
                                  "hymba-1.5b", "whisper-small"])
def test_decode_cell_small_mesh(arch):
    _run(arch, "decode_32k")


def test_multipod_mesh_train():
    _run("qwen2-1.5b", "train_4k", ndev=8, mesh_shape="(2, 2, 2)",
         mesh_axes='("pod", "data", "model")')


def test_long500k_skips_full_attention():
    import jax
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config
    from repro.launch.steps import skip_reason
    assert skip_reason(get_config("llama3-405b"), SHAPES["long_500k"])
    assert skip_reason(get_config("rwkv6-3b"), SHAPES["long_500k"]) is None
    assert skip_reason(get_config("hymba-1.5b"), SHAPES["long_500k"]) is None
