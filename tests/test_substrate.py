"""Substrate tests: optimizers, schedules, accumulation, checkpointing,
fault tolerance, compression, data pipeline."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs.registry import build, smoke_config
from repro.core.bk import DPConfig, bk_private_grad
from repro.data.pipeline import Pipeline, PipelineConfig
from repro.models.mlp import MLP, MLPConfig
from repro.optim.accumulate import accumulated_private_grad
from repro.optim.optimizers import make_optimizer
from repro.optim.schedules import make_schedule, warmup_cosine
from repro.runtime.compression import dequantize, quantize
from repro.runtime.fault_tolerance import (CheckpointManager, Heartbeat,
                                           PreemptionGuard)
from repro.utils.tree import flatten


def _setup():
    model = MLP(MLPConfig(d_in=8, width=16, depth=2, n_classes=4))
    params = model.init(jax.random.PRNGKey(0))
    batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (8, 8)),
             "y": jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 4)}
    return model, params, batch


# ------------------------------------------------------------------ optimizers
@pytest.mark.parametrize("name", ["sgd", "adamw", "lamb", "adafactor"])
def test_optimizer_reduces_loss(name):
    model, params, batch = _setup()
    opt = make_optimizer(name, lambda s: jnp.asarray(3e-2), weight_decay=0.0)
    state = opt.init(params)
    from repro.core.tape import Tape

    def loss(p):
        return jnp.mean(model.apply(p, batch, Tape(None)))

    l0 = loss(params)
    step_fn = jax.jit(lambda p, s, i: opt.update(jax.grad(loss)(p), s, p, i))
    for i in range(25):
        params, state = step_fn(params, state, jnp.asarray(i))
    assert loss(params) < l0 - 0.05


def test_schedule_shapes():
    fn = warmup_cosine(1e-3, warmup=10, total=100)
    vals = [float(fn(jnp.asarray(s))) for s in [0, 5, 10, 50, 100]]
    assert vals[0] < vals[1] < vals[2]          # warmup ramps
    assert vals[2] >= vals[3] >= vals[4]        # cosine decays
    assert make_schedule("constant", 1.0)(jnp.asarray(7)) == 1.0


# ---------------------------------------------------------------- accumulation
def test_accumulation_matches_full_batch():
    """Microbatched clipped sums + single noise == full-batch BK exactly."""
    model, params, batch = _setup()
    cfg = DPConfig(mode="bk", sigma=0.5)
    rng = jax.random.PRNGKey(9)
    full, _ = jax.jit(lambda p, b, r: bk_private_grad(model.apply, p, b, r, cfg))(
        params, batch, rng)
    acc, _ = jax.jit(lambda p, b, r: accumulated_private_grad(
        model.apply, p, b, r, cfg, microbatch=2))(params, batch, rng)
    for (p, g), (_, r) in zip(sorted(flatten(acc).items()),
                              sorted(flatten(full).items())):
        np.testing.assert_allclose(g, r, rtol=1e-5, atol=1e-6, err_msg=p)


# --------------------------------------------------------------- checkpointing
def test_checkpoint_roundtrip(tmp_path):
    model, params, _ = _setup()
    state = {"params": params, "step": jnp.asarray(7)}
    ckpt.save(str(tmp_path), 7, state)
    restored, step, _ = ckpt.restore(str(tmp_path))
    assert step == 7
    for p, v in flatten(state).items():
        np.testing.assert_array_equal(np.asarray(v), flatten(restored)[p])


def test_checkpoint_keep_k_and_latest(tmp_path):
    model, params, _ = _setup()
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(str(tmp_path), s, {"params": params}, keep=2)
    assert ckpt.steps(str(tmp_path)) == [4, 5]
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_checkpoint_detects_corruption(tmp_path):
    model, params, _ = _setup()
    ckpt.save(str(tmp_path), 1, {"params": params})
    ckpt.save(str(tmp_path), 2, {"params": params})
    # corrupt step 2's payload -> latest valid falls back to step 1
    bad = os.path.join(str(tmp_path), "step_0000000002", "shards.00000.npz")
    with open(bad, "wb") as f:
        f.write(b"garbage")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore applies new shardings (single-device degenerate mesh here,
    exercising the device_put path)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    model, params, _ = _setup()
    ckpt.save(str(tmp_path), 3, {"params": params})
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    sh = NamedSharding(mesh, P())
    restored, _, _ = ckpt.restore(str(tmp_path), shardings=sh)
    leaf = flatten(restored)["params/l0/w"]
    assert leaf.sharding == sh


# -------------------------------------------------------------- fault tolerance
def test_preemption_guard_and_manager(tmp_path):
    model, params, _ = _setup()
    guard = PreemptionGuard(install=False)
    mgr = CheckpointManager(root=str(tmp_path), every=2, keep=2,
                            async_save=False)
    saved = []
    for step in range(5):
        if mgr.maybe_save(step, {"params": params, "step": jnp.asarray(step)}):
            saved.append(step)
        if step == 3:
            guard.request_stop()
        if guard.should_stop():
            mgr.maybe_save(step, {"params": params, "step": jnp.asarray(step)},
                           force=True)
            break
    state, step, _ = mgr.resume()
    assert step == 3  # the preemption save
    assert saved == [0, 2]


def test_heartbeat_detects_stall():
    stalls = []
    hb = Heartbeat(timeout_s=0.2, on_stall=stalls.append, poll_s=0.05)
    hb.beat(0)
    time.sleep(0.5)
    hb.close()
    assert stalls and stalls[0].last_step == 0
    assert stalls[0].seconds_since_beat > 0.2
    assert "stall" in stalls[0].describe()


# ----------------------------------------------------------------- compression
def test_quantize_unbiased_and_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 3.0
    qs = [dequantize(*quantize(x, jax.random.PRNGKey(i))) for i in range(30)]
    mean = np.mean([np.asarray(q) for q in qs], axis=0)
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    np.testing.assert_allclose(mean, np.asarray(x), atol=scale)  # unbiased
    q, s = quantize(x, jax.random.PRNGKey(0))
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(dequantize(q, s), np.asarray(x), atol=s + 1e-6)


def test_compressed_allreduce_multidevice_subprocess():
    """Run the pod-axis compressed reduce on 4 virtual devices."""
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.runtime.compression import compressed_allreduce_mean
        mesh = Mesh(np.array(jax.devices()).reshape(4), ("pod",))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 128))
        rngs = jax.random.split(jax.random.PRNGKey(1), 4)
        f = shard_map(lambda xs, rs: compressed_allreduce_mean(xs[0], rs[0], "pod")[None],
                      mesh=mesh, in_specs=(P("pod"), P("pod")), out_specs=P("pod"))
        got = f(x, rngs)
        want = jnp.mean(x, axis=0)
        scale = float(jnp.max(jnp.abs(x))) / 127.0
        for i in range(4):
            np.testing.assert_allclose(got[i], want, atol=2 * scale)
        print("OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(__file__)),
                       env=env, timeout=300)
    assert "OK" in r.stdout, r.stderr[-2000:]


# ------------------------------------------------------------------- pipeline
def test_pipeline_deterministic_resume():
    cfg = smoke_config("qwen2-1.5b")
    pipe = Pipeline(cfg, PipelineConfig(batch=4, seq_len=8, seed=3))
    b5a = pipe.batch(5)
    b5b = Pipeline(cfg, PipelineConfig(batch=4, seq_len=8, seed=3)).batch(5)
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    assert not np.array_equal(pipe.batch(6)["tokens"], b5a["tokens"])


def test_pipeline_poisson_mask():
    cfg = smoke_config("qwen2-1.5b")
    pipe = Pipeline(cfg, PipelineConfig(batch=16, seq_len=8, seed=0,
                                        poisson_q=0.5))
    b = pipe.batch(0)
    assert "mask" in b and b["mask"].shape == b["tokens"].shape
    frac = float(b["mask"][:, 0].mean())
    assert 0.1 < frac < 0.9
