"""Sharded, donation-clean train step: parity vs single device, shard-local
noise (slice-sized buffers, determinism, variance), donation safety, and the
step-benchmark artifact.

Multi-device tests run in a subprocess (XLA_FLAGS must set the fake device
count before jax's first import), mirroring test_dryrun_small."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, ndev: int = 8, timeout: int = 560):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=timeout, cwd=ROOT)
    assert r.returncode == 0 and "OK" in r.stdout, (r.stdout[-1500:] +
                                                    r.stderr[-3000:])
    return r.stdout


PARITY = textwrap.dedent("""
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.registry import build, smoke_config
    from repro.core.bk import DPConfig
    from repro.data.pipeline import Pipeline, PipelineConfig
    from repro.launch.steps import TrainState, make_train_step
    from repro.optim.optimizers import make_optimizer
    from repro.utils.tree import flatten

    assert len(jax.devices()) == 8
    cfg = smoke_config("qwen2-1.5b").with_(dtype="float32",
                                           param_dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = Pipeline(cfg, PipelineConfig(8, 16, seed=0))
    # sigma=0: the full clipping pipeline runs but parity is noise-free
    # (shard-local noise is keyed per shard, so sigma>0 runs are
    # statistically — not bitwise — identical across meshes)
    dp = DPConfig(mode="bk-mixopt", sigma=0.0)

    def run(mesh, microbatch, steps=3):
        opt = make_optimizer("adamw", lambda s: jnp.asarray(1e-3, jnp.float32))
        fn, state_sh, batch_sh = make_train_step(
            model.apply, params, opt, "adamw", dp, microbatch, mesh,
            pipe.batch(0))
        jitted = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None), donate_argnums=(0,))
        # device_put to an ALREADY-matching sharding aliases the buffers;
        # copy first so this run's donation cannot delete the shared init
        p0 = jax.tree_util.tree_map(lambda x: jnp.array(x, copy=True), params)
        state = TrainState(params=jax.device_put(p0, state_sh.params),
                           opt_state=jax.device_put(opt.init(p0),
                                                    state_sh.opt_state),
                           step=jnp.asarray(0, jnp.int32),
                           rng=jax.random.PRNGKey(1))
        for step in range(steps):
            batch = jax.device_put(pipe.batch(step), batch_sh)
            state, loss = jitted(state, batch)
        return jax.device_get(state.params), float(loss)

    mesh8 = jax.make_mesh((4, 2), ("data", "model"))
    mesh1 = jax.make_mesh((1, 1), ("data", "model"),
                          devices=jax.devices()[:1])
    for mb in (0, 4):   # full batch AND the microbatch lax.scan path
        p8, l8 = run(mesh8, mb)
        p1, l1 = run(mesh1, mb)
        for k, v in flatten(p1).items():
            # 3 adamw steps amplify cross-shard reduction-order fp noise
            # through the scale-free m/sqrt(v); observed worst ~4e-6 abs
            np.testing.assert_allclose(np.asarray(flatten(p8)[k]),
                                       np.asarray(v), rtol=1e-3, atol=1e-5,
                                       err_msg=f"mb={mb} {k}")
        assert abs(l8 - l1) < 1e-4, (mb, l8, l1)
    print("OK parity")
""")


def test_sharded_step_matches_single_device():
    """Same seed => numerically matching params after N donated steps on a
    (4 data x 2 model) mesh vs a single device, full-batch and microbatched."""
    _run(PARITY)


NOISE_HLO = textwrap.dedent("""
    import re
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.bk import DPConfig
    from repro.core.policy import as_policy, finalize_noise, resolve_policy
    from repro.launch import sharding as sh

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    # 'head/w' shards ('data','model') -> per-device slice (16, 24)
    params = {"head": {"w": jnp.zeros((64, 48))}}
    pspecs = sh.flat_param_pspecs(params, mesh)
    assert tuple(pspecs["head/w"]) == ("data", "model"), pspecs
    policy = as_policy(DPConfig(mode="bk", sigma=1.0, R=1.0))
    res = resolve_policy(policy, ["head/w"])

    def noised(sums, rng):
        return finalize_noise(policy, res, sums, rng, 1.0, mesh=mesh,
                              pspecs=pspecs)

    ssh = {"head/w": NamedSharding(mesh, pspecs["head/w"])}
    f = jax.jit(noised, in_shardings=(ssh, None))
    sums = jax.device_put({"head/w": jnp.zeros((64, 48))}, ssh)
    rng = jax.random.PRNGKey(3)
    txt = f.lower(sums, rng).compile().as_text()
    # the SPMD-partitioned program must hold ONLY slice-sized f32 buffers:
    # a replicated full-param noise tensor would show up as f32[64,48]
    assert "f32[16,24]" in txt, txt[:2000]
    assert "f32[64,48]" not in txt
    assert "f32[3072]" not in txt  # nor a flattened full-size draw

    # determinism: same (key, mesh) -> bitwise-identical shard-local noise
    n1 = np.asarray(f(sums, rng)["head/w"])
    n2 = np.asarray(f(sums, rng)["head/w"])
    np.testing.assert_array_equal(n1, n2)
    # moments: mean 0, std sigma * S (= 1.0 here) over the full tensor
    assert abs(n1.mean()) < 0.1 and abs(n1.std() - 1.0) < 0.1, \
        (n1.mean(), n1.std())
    # distinct shards draw from distinct fold_in keys
    assert not np.array_equal(n1[:16, :24], n1[16:32, :24])
    print("OK noise hlo")
""")


def test_shard_local_noise_slice_sized_hlo():
    """No replicated full-param noise: every f32 buffer in the lowered
    finalize_noise program is per-device slice-sized; draws are
    deterministic with correct moments and differ across shards."""
    _run(NOISE_HLO)


NOISE_DEVCOUNT = textwrap.dedent("""
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core.noise import counter_normal, sharded_normal

    rng = jax.random.PRNGKey(5)
    shape = (64, 32)
    from jax.sharding import PartitionSpec as P
    # the counter-based generator is the portable ground truth: every mesh
    # (and the no-mesh path) must reproduce it BITWISE
    ref = np.asarray(counter_normal(rng, shape))
    assert abs(ref.mean()) < 0.1 and abs(ref.std() - 1.0) < 0.1, ref.std()
    for nd in (1, 2, 8):
        mesh = jax.make_mesh((nd, 1), ("data", "model"),
                             devices=jax.devices()[:nd])
        x = np.asarray(sharded_normal(rng, shape, mesh=mesh,
                                      spec=P("data", None)))
        # sigma>0 runs are mesh-PORTABLE: same (key, shape) -> same noise
        # at every device count (not merely statistically matched)
        np.testing.assert_array_equal(x, ref, err_msg=str(nd))
    # sharding BOTH dims on a 2-D mesh still assembles the same tensor
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    x = np.asarray(sharded_normal(rng, shape, mesh=mesh,
                                  spec=P("data", "model")))
    np.testing.assert_array_equal(x, ref)
    # non-divisible dims fall back (same values, GSPMD-partitioned)
    z = sharded_normal(rng, (63, 32), mesh=jax.make_mesh(
        (8, 1), ("data", "model")), spec=P("data", None))
    assert z.shape == (63, 32)
    np.testing.assert_array_equal(np.asarray(z),
                                  np.asarray(counter_normal(rng, (63, 32))))
    print("OK devcounts")
""")


def test_shard_local_noise_bitwise_portable_across_device_counts():
    """Counter-based noise indexed by global coordinates: draws at 1/2/8
    shards (and 2-D meshes) are bitwise identical, so sigma>0 runs are
    mesh-portable; non-divisible dims fall back to the same values."""
    _run(NOISE_DEVCOUNT)


def test_counter_normal_wide_counter_consistency():
    """Tensors past 2^32 elements split the counter across both threefry
    words: blocks of a huge virtual tensor agree across decompositions,
    distinct leading blocks differ, and a single dim >= 2^32 raises."""
    import jax
    import pytest as _pytest

    from repro.core.noise import counter_normal

    rng = jax.random.PRNGKey(5)
    full = (1 << 20, 1 << 16)          # 2^36 virtual elements
    a = np.asarray(counter_normal(rng, (2, 4), offsets=(12345, 67),
                                  full_shape=full))
    r0 = np.asarray(counter_normal(rng, (1, 4), offsets=(12345, 67),
                                   full_shape=full))
    r1 = np.asarray(counter_normal(rng, (1, 4), offsets=(12346, 67),
                                   full_shape=full))
    np.testing.assert_array_equal(a[0:1], r0)
    np.testing.assert_array_equal(a[1:2], r1)
    assert not np.array_equal(r0, r1)
    far = np.asarray(counter_normal(rng, (1, 8), offsets=(1 << 19, 0),
                                    full_shape=full))
    assert np.isfinite(far).all() and len(np.unique(far)) > 1
    with _pytest.raises(ValueError, match="2\\^64|2\\^32"):
        counter_normal(rng, (4,), offsets=(0,), full_shape=(1 << 33,))


PADDED = textwrap.dedent("""
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.registry import build, smoke_config
    from repro.core.bk import DPConfig, bk_private_grad, pad_batch
    from repro.data.pipeline import Pipeline, PipelineConfig
    from repro.utils.tree import flatten

    cfg = smoke_config("qwen2-1.5b").with_(dtype="float32",
                                           param_dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # B=6 does NOT divide the 4-way data axis: the engine must pad to 8
    # with masked samples and still take the shard_map'd kernel path
    pipe = Pipeline(cfg, PipelineConfig(6, 16, seed=0))
    batch = pipe.batch(0)
    dp = DPConfig(mode="bk-mixopt", sigma=0.0)
    mesh8 = jax.make_mesh((4, 2), ("data", "model"))
    mesh1 = jax.make_mesh((1, 1), ("data", "model"),
                          devices=jax.devices()[:1])

    padded, mask, Bp = pad_batch(batch, mesh8, 6)
    assert Bp == 8 and mask.shape == (8,), (Bp, mask.shape)
    assert float(mask.sum()) == 6.0
    # the padded shapes divide: the kernel path engages instead of the
    # GSPMD-einsum fallback
    from repro.core.bk import batch_shard
    assert batch_shard(mesh8, Bp) is not None
    assert batch_shard(mesh8, 6) is None

    def grads(mesh):
        with mesh:
            g, aux = jax.jit(
                lambda p, b: bk_private_grad(model.apply, p, b,
                                             jax.random.PRNGKey(7), dp,
                                             mesh=mesh))(params, batch)
        return jax.device_get(g), aux

    g8, aux8 = grads(mesh8)
    g1, aux1 = grads(mesh1)
    # aux reports REAL samples only (pad rows are invisible)
    assert np.asarray(aux8["per_sample_norms"]).shape == (6,)
    np.testing.assert_allclose(np.asarray(aux8["per_sample_norms"]),
                               np.asarray(aux1["per_sample_norms"]),
                               rtol=1e-4, atol=1e-6)
    for k, v in flatten(g1).items():
        np.testing.assert_allclose(np.asarray(flatten(g8)[k]),
                                   np.asarray(v), rtol=1e-3, atol=1e-5,
                                   err_msg=k)
    print("OK padded")
""")


def test_padded_batch_parity_on_mesh():
    """A non-divisible batch (B=6 on a 4-way data axis) is padded with
    masked samples, engages the shard_map'd kernel path, and matches the
    single-device gradients; aux reports real samples only."""
    _run(PADDED)


def test_donated_step_checkpoint_safety(tmp_path):
    """The step donates the whole TrainState; a checkpoint save issued
    right after a step (async writer) must still see valid arrays — the
    copy-before-donate snapshot happens synchronously in maybe_save."""
    import jax
    import jax.numpy as jnp

    from repro.checkpoint import checkpoint as ckpt
    from repro.configs.registry import build, smoke_config
    from repro.core.bk import DPConfig
    from repro.data.pipeline import Pipeline, PipelineConfig
    from repro.launch.mesh import make_train_mesh
    from repro.launch.steps import TrainState, make_train_step
    from repro.optim.optimizers import make_optimizer
    from repro.runtime.fault_tolerance import CheckpointManager
    from repro.utils.tree import flatten

    cfg = smoke_config("qwen2-1.5b").with_(dtype="float32",
                                           param_dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer("adamw", lambda s: jnp.asarray(1e-3, jnp.float32))
    mesh = make_train_mesh()
    pipe = Pipeline(cfg, PipelineConfig(4, 16, seed=0))
    fn, state_sh, batch_sh = make_train_step(
        model.apply, params, opt, "adamw", DPConfig(mode="bk", sigma=0.1), 0,
        mesh, pipe.batch(0))
    jitted = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
    # commit the initial state to the step's shardings: an uncommitted
    # state would be COPIED to match in_shardings and only the copy donated
    state = TrainState(params=jax.device_put(params, state_sh.params),
                       opt_state=jax.device_put(opt.init(params),
                                                state_sh.opt_state),
                       step=jnp.asarray(0, jnp.int32),
                       rng=jax.random.PRNGKey(1))

    mgr = CheckpointManager(str(tmp_path), every=1, keep=2)
    for step in range(2):
        old = state
        state, loss = jitted(state, jax.device_put(pipe.batch(step),
                                                   batch_sh))
        # donation really happened: the consumed state's buffers are gone
        assert jax.tree_util.tree_leaves(old.params)[0].is_deleted()
        # async save of the NEW state while the next step will donate it
        mgr.maybe_save(step, {"params": state.params,
                              "opt": state.opt_state,
                              "step": np.asarray(step)})
        old = state
    mgr.wait()
    restored, rstep, _ = ckpt.restore(str(tmp_path))
    assert rstep == 1
    live = flatten(jax.device_get(state.params))
    for k, v in flatten(restored["params"]).items():
        assert np.all(np.isfinite(v)), k
        np.testing.assert_array_equal(v, np.asarray(live[k]), err_msg=k)


def test_host_snapshot_copies_out_of_device():
    """ckpt.host_snapshot returns plain numpy even for donated-soon arrays."""
    import jax.numpy as jnp

    from repro.checkpoint.checkpoint import host_snapshot

    snap = host_snapshot({"a": {"w": jnp.ones((3, 3))}, "s": jnp.asarray(4)})
    assert isinstance(snap["a"]["w"], np.ndarray)
    assert snap["s"] == 4


BENCH = os.path.join(ROOT, "BENCH_step.json")


@pytest.mark.skipif(not os.path.exists(BENCH),
                    reason="BENCH_step.json not generated yet "
                           "(benchmarks.step_bench writes it; ci.sh runs it)")
def test_step_bench_artifact_schema():
    """The committed step-level baseline covers >= 2 modes x >= 2 device
    counts with tokens/s and peak-HBM cells."""
    with open(BENCH) as f:
        data = json.load(f)
    cells = data["cells"]
    modes = {c["mode"] for c in cells}
    devs = {c["devices"] for c in cells}
    assert len(modes) >= 2, modes
    assert len(devs) >= 2, devs
    for c in cells:
        assert c["tokens_per_s"] > 0
        assert c["steps_per_s"] > 0
        assert c["peak_hbm_bytes"]["total"] > 0
