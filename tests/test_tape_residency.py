"""Tape residency subsystem: the streamed BK backward (chunked transposed
sweeps + per-tap storage policies) against the monolithic-vjp oracle, the
dispatch residency planner, and the policy/report wiring.

Documented parity tolerances (acceptance: ISSUE 5):
  native     bitwise — the streamed engine with every tap stored native IS
             the monolithic vjp's computation
  recompute  tight allclose (the re-derived cotangents are the same
             transposed computation; only the mixopt cache path, which a
             non-native tape policy suppresses, can reassociate reductions)
  bf16       rtol 1e-2 / atol 5e-3 (one bf16 round-trip on ds + acts)
  int8       atol 5e-2 (8-bit stochastic rounding, per-tensor scale)
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bk import DPConfig, bk_clipped_sum, monolithic_clipped_sum
from repro.core.engine import ALL_MODES, PrivacyEngine, make_grad_fn
from repro.core.policy import ParamGroup, PrivacyPolicy
from repro.core.tape import TAPE_POLICIES, load_record, store_record
from repro.kernels import dispatch
from repro.models.mlp import MLP, MLPConfig
from repro.utils.tree import flatten

B = 8
BK = ("bk", "bk-mixghost", "bk-mixopt")


def _setup():
    model = MLP(MLPConfig(d_in=12, width=16, depth=3, n_classes=5, bias=True))
    params = model.init(jax.random.PRNGKey(0))
    batch = {
        "x": jax.random.normal(jax.random.PRNGKey(1), (B, 12)),
        "y": jax.random.randint(jax.random.PRNGKey(2), (B,), 0, 5),
    }
    return model, params, batch


def _assert_tree(got, want, *, bitwise=False, rtol=1e-5, atol=1e-6, msg=""):
    for k, v in flatten(want).items():
        g = np.asarray(flatten(got)[k])
        if bitwise:
            np.testing.assert_array_equal(g, np.asarray(v),
                                          err_msg=f"{msg} {k}")
        else:
            np.testing.assert_allclose(g, np.asarray(v), rtol=rtol,
                                       atol=atol, err_msg=f"{msg} {k}")


TOLS = {"native": dict(bitwise=True),
        "recompute": dict(rtol=1e-5, atol=1e-6),
        "bf16": dict(rtol=1e-2, atol=5e-3),
        "int8": dict(atol=5e-2, rtol=0.0)}


@pytest.mark.parametrize("mode", BK)
@pytest.mark.parametrize("tape,chunks", [("native", 1), ("recompute", 1),
                                         ("recompute", 3), ("bf16", 1),
                                         ("int8", 1)])
def test_streamed_matches_monolithic(mode, tape, chunks):
    """The streamed engine vs the pre-residency monolithic-vjp oracle, per
    BK mode x storage policy, at the documented tolerances."""
    model, params, batch = _setup()
    ref, raux = jax.jit(
        lambda p, b: monolithic_clipped_sum(model.apply, p, b,
                                            DPConfig(mode=mode)))(params, batch)
    cfg = DPConfig(mode=mode, tape_policy=tape, tape_chunks=chunks)
    got, aux = jax.jit(
        lambda p, b: bk_clipped_sum(model.apply, p, b, cfg,
                                    rng=jax.random.PRNGKey(3)))(params, batch)
    _assert_tree(got, ref, **TOLS[tape], msg=f"{mode}/{tape}")
    # fp32 norm accumulation is preserved: per-sample norms track the oracle
    # even when the held state is compressed
    np.testing.assert_allclose(np.asarray(aux["per_sample_norms"]),
                               np.asarray(raux["per_sample_norms"]),
                               rtol=5e-2 if tape == "int8" else 1e-2,
                               atol=1e-3)


@pytest.mark.parametrize("mode", BK)
@pytest.mark.parametrize("tape", ["native", "bf16", "int8"])
def test_streamed_matches_monolithic_layer_scope(mode, tape):
    """The monolithic oracle accepts layer-scope policies (it is
    unit-generic), so the documented tolerances extend to the new scope.
    Under scope='layer' every single-tap unit streams (fused phase-2+3 at
    the tap); 'native' is allclose rather than bitwise because the fused
    kernel reassociates one reduction, while compressed stores keep their
    flat-scope tolerances."""
    from repro.core.policy import with_scope
    model, params, batch = _setup()
    policy = with_scope(DPConfig(mode=mode, tape_policy=tape,
                                 clipping="automatic"), "layer")
    ref, raux = jax.jit(
        lambda p, b: monolithic_clipped_sum(model.apply, p, b,
                                            policy))(params, batch)
    got, aux = jax.jit(
        lambda p, b: bk_clipped_sum(model.apply, p, b, policy,
                                    rng=jax.random.PRNGKey(3)))(params, batch)
    tol = dict(rtol=1e-4, atol=1e-6) if tape == "native" else TOLS[tape]
    _assert_tree(got, ref, **tol, msg=f"layer/{mode}/{tape}")
    np.testing.assert_allclose(np.asarray(aux["per_sample_norms"]),
                               np.asarray(raux["per_sample_norms"]),
                               rtol=5e-2 if tape == "int8" else 1e-2,
                               atol=1e-3)


@pytest.mark.parametrize("mode", ALL_MODES)
def test_tape_policy_across_all_modes(mode):
    """All 8 modes accept a tape policy: BK modes stream (recompute matches
    the default-path gradients), baselines hold no tap state so the knob is
    an exact no-op."""
    model, params, batch = _setup()
    rng = jax.random.PRNGKey(7)
    ref, _ = jax.jit(make_grad_fn(model.apply, DPConfig(mode=mode)))(
        params, batch, rng)
    cfg = DPConfig(mode=mode, tape_policy="recompute", tape_chunks=2)
    got, _ = jax.jit(make_grad_fn(model.apply, cfg))(params, batch, rng)
    if mode in BK:
        _assert_tree(got, ref, rtol=1e-5, atol=1e-6, msg=mode)
    else:
        _assert_tree(got, ref, bitwise=True, msg=mode)


def test_per_group_tape_override():
    """ParamGroup.tape wins over the policy default per tap; mixed
    residency (one group recomputed, the rest bf16) still matches."""
    model, params, batch = _setup()
    ref, _ = jax.jit(
        lambda p, b: monolithic_clipped_sum(model.apply, p, b,
                                            DPConfig(mode="bk")))(params, batch)
    policy = PrivacyPolicy(groups=(
        ParamGroup("first", "l0", tape="recompute"),
        ParamGroup("rest", ".*"),
    ), mode="bk", tape_policy="bf16")
    # the override is visible in the report: l0's tap recomputes, the rest
    # hold bf16
    report = PrivacyEngine(model.apply, policy).kernel_report(params, batch)
    stores = {k: p["tape"].store for k, p in report.items()}
    assert stores["l0#mm"] == "recompute", stores
    assert all(s == "bf16" for k, s in stores.items() if k != "l0#mm"), stores
    got, _ = jax.jit(
        lambda p, b: bk_clipped_sum(model.apply, p, b, policy))(params, batch)
    _assert_tree(got, ref, rtol=1e-2, atol=5e-3, msg="mixed")


def test_policy_validation():
    with pytest.raises(ValueError, match="tape_policy"):
        PrivacyPolicy(groups=(ParamGroup("all", ".*"),), tape_policy="zip")
    with pytest.raises(ValueError, match="tape_chunks"):
        PrivacyPolicy(groups=(ParamGroup("all", ".*"),), tape_chunks=0)
    with pytest.raises(ValueError, match="tape"):
        ParamGroup("g", ".*", tape="fp8")


# ----------------------------------------------------------------- planner
def test_tape_plan_thresholds():
    """The analytic residency rule: small holds native, mid compresses,
    big re-derives; hold_bytes tracks the store; explicit stores pin."""
    dispatch.clear_cache()
    small = dispatch.tape_plan("mm", (2, 4, 8), (2, 4, 8), "auto")
    assert small.store == "native" and small.hold_bytes == 4 * 2 * 4 * 8
    mid = dispatch.tape_plan("mm", (8, 512, 64), (8, 512, 64), "auto")
    assert mid.store == "bf16" and mid.hold_bytes == 2 * 8 * 512 * 64
    big = dispatch.tape_plan("mm", (64, 2048, 512), (64, 2048, 512), "auto")
    assert big.store == "recompute" and big.hold_bytes == 0
    assert big.recompute_flops == 2 * 64 * 2048 * 512 * 512
    pinned = dispatch.tape_plan("mm", (64, 2048, 512), (64, 2048, 512),
                                "int8")
    assert pinned.store == "int8"
    assert pinned.hold_bytes == 64 * 2048 * 512 + 4


def test_tape_plan_env_force():
    dispatch.clear_cache()
    os.environ["REPRO_TAPE"] = "recompute"
    try:
        p = dispatch.tape_plan("mm", (2, 4, 8), (2, 4, 8), "auto")
        assert p.store == "recompute"
    finally:
        del os.environ["REPRO_TAPE"]
    dispatch.clear_cache()


def test_fit_tape_budget():
    """Budget fitting upgrades biggest-first until the held bytes fit."""
    dispatch.clear_cache()
    plans = {
        "a": dispatch.tape_plan("mm", (4, 64, 32), (4, 64, 32), "native"),
        "b": dispatch.tape_plan("mm", (16, 256, 64), (16, 256, 64), "native"),
    }
    total = sum(p.hold_bytes for p in plans.values())
    fitted = dispatch.fit_tape_budget(plans, total // 4)
    assert sum(p.hold_bytes for p in fitted.values()) <= total // 4
    # the big tap was upgraded further than the small one
    assert fitted["b"].store == "recompute"
    # an impossible budget degrades gracefully to all-recompute
    floor = dispatch.fit_tape_budget(plans, 0)
    assert all(p.store == "recompute" for p in floor.values())


def test_kernel_report_includes_tape():
    model, params, batch = _setup()
    eng = PrivacyEngine(model.apply,
                        DPConfig(mode="bk-mixopt", tape_policy="recompute"))
    report = eng.kernel_report(params, batch)
    assert report
    for key, plans in report.items():
        assert set(plans) == {"norm", "grad", "tape"}, key
        assert plans["tape"].store == "recompute"
        assert plans["tape"].hold_bytes == 0


# ------------------------------------------------------------- store / load
def test_store_load_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (6, 10))
    rng = jax.random.PRNGKey(1)
    assert store_record(x, "native") is x
    assert store_record(x, "recompute") is x      # caller drops, not store
    bf = store_record(x, "bf16")
    assert bf.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(load_record(bf, x.dtype)),
                               np.asarray(x), rtol=1e-2, atol=1e-2)
    q = store_record(x, "int8", rng)
    assert q["q"].dtype == jnp.int8
    scale = float(q["scale"])
    np.testing.assert_allclose(np.asarray(load_record(q, x.dtype)),
                               np.asarray(x), atol=scale + 1e-7)
    with pytest.raises(ValueError):
        store_record(x, "fp4")


def test_store_load_integer_and_moe_records():
    ids = jnp.arange(12, dtype=jnp.int32).reshape(3, 4)
    assert store_record(ids, "int8") is ids       # ids stay exact
    moe = {"a": jax.random.normal(jax.random.PRNGKey(0), (2, 2, 3, 4)),
           "mask": jnp.ones((2, 2, 3), jnp.bool_)}
    s = store_record(moe, "bf16")
    assert s["a"].dtype == jnp.bfloat16 and s["mask"] is moe["mask"]
    out = load_record(s, moe["a"].dtype)
    assert out["a"].dtype == moe["a"].dtype
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(moe["a"]),
                               rtol=1e-2, atol=1e-2)
    assert load_record(ids) is ids


def test_tape_policies_exported():
    assert TAPE_POLICIES == ("native", "bf16", "int8", "recompute", "auto")
