"""Fault-tolerance runtime coverage: PreemptionGuard signal handling,
Heartbeat stall detection (structured reports), CheckpointManager
save/wait/resume ordering, and the fault-injection harness itself."""
import os
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.runtime import fault_injection as fi
from repro.runtime.fault_tolerance import (CheckpointManager, Heartbeat,
                                           PreemptionGuard, StallReport)


# ----------------------------------------------------------- PreemptionGuard
def test_preemption_guard_handles_sigterm():
    old = signal.getsignal(signal.SIGTERM)
    try:
        guard = PreemptionGuard(install=True)
        assert not guard.should_stop()
        os.kill(os.getpid(), signal.SIGTERM)
        # signal delivery is synchronous in the main thread once kill returns
        assert guard.should_stop()
    finally:
        signal.signal(signal.SIGTERM, old)


def test_preemption_guard_request_stop_without_signal():
    guard = PreemptionGuard(install=False)
    assert not guard.should_stop()
    guard.request_stop()
    assert guard.should_stop()


def test_preemption_guard_off_main_thread_is_safe():
    """Installing from a non-main thread must not raise (signal.signal does);
    request_stop still works."""
    out = {}

    def run():
        g = PreemptionGuard(install=True)
        g.request_stop()
        out["stopped"] = g.should_stop()

    t = threading.Thread(target=run)
    t.start()
    t.join()
    assert out["stopped"]


# ----------------------------------------------------------------- Heartbeat
def test_heartbeat_quiet_while_beating():
    stalls = []
    hb = Heartbeat(timeout_s=0.4, on_stall=stalls.append, poll_s=0.05)
    for s in range(6):
        hb.beat(s)
        time.sleep(0.05)
    hb.close()
    assert stalls == [] and not hb.stalled


def test_heartbeat_stall_report_is_structured():
    stalls = []
    hb = Heartbeat(timeout_s=0.15, on_stall=stalls.append, poll_s=0.05)
    hb.beat(7)
    time.sleep(0.45)
    hb.close()
    assert stalls, "watchdog never fired"
    rep = stalls[0]
    assert isinstance(rep, StallReport)
    assert rep.last_step == 7
    assert rep.seconds_since_beat > 0.15
    assert rep.timeout_s == 0.15
    assert rep.backend == jax.default_backend()
    assert str(rep.last_step) in rep.describe()


def test_heartbeat_recovers_after_beat():
    hb = Heartbeat(timeout_s=0.15, on_stall=lambda r: None, poll_s=0.05)
    time.sleep(0.3)
    assert hb.stalled
    hb.beat(1)
    assert not hb.stalled
    hb.close()


# --------------------------------------------------------- CheckpointManager
def _state(v: float):
    return {"params": {"w": jnp.full((4, 4), v)}, "step": np.asarray(0)}


def test_manager_save_cadence_and_force(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=3, keep=10,
                            async_save=False)
    saved = [s for s in range(7) if mgr.maybe_save(s, _state(float(s)))]
    assert saved == [0, 3, 6]
    assert not mgr.maybe_save(7, _state(7.0))
    assert mgr.maybe_save(7, _state(7.0), force=True)
    assert ckpt.steps(str(tmp_path)) == [0, 3, 6, 7]


def test_manager_async_wait_ordering(tmp_path):
    """An async save is complete after wait(); a second save (or resume)
    joins the in-flight writer before starting, so the newest checkpoint
    always wins and no torn interleaving is possible."""
    mgr = CheckpointManager(str(tmp_path), every=1, keep=10, async_save=True)
    assert mgr.maybe_save(0, _state(0.0))
    assert mgr.maybe_save(1, _state(1.0))  # joins save(0) first
    mgr.wait()
    assert ckpt.steps(str(tmp_path)) == [0, 1]
    state, step, _ = mgr.resume()
    assert step == 1
    np.testing.assert_array_equal(state["params"]["w"],
                                  np.full((4, 4), 1.0))


def test_manager_meta_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, async_save=False)
    meta = {"run_state_version": 1, "ledger": {"recorded_to": 5}}
    mgr.maybe_save(4, _state(2.0), meta=meta)
    _, step, got = mgr.resume()
    assert step == 4 and got == meta


def test_manager_resume_empty(tmp_path):
    state, step, meta = CheckpointManager(str(tmp_path)).resume()
    assert state is None and step == -1 and meta == {}


# ------------------------------------------------------------ fault injection
def test_parse_fault_grammar():
    spec = fi.parse_fault("step@7:sigterm")
    assert spec == fi.FaultSpec("step", 7, "sigterm")
    assert fi.parse_fault(spec.encode()) == spec
    assert fi.parse_fault("ckpt_mid_write") == \
        fi.FaultSpec("ckpt_mid_write", None, "sigkill")
    assert fi.parse_fault("") is None
    with pytest.raises(ValueError, match="action"):
        fi.parse_fault("step:explode")
    with pytest.raises(ValueError, match="site"):
        fi.parse_fault("@3:sigkill")


def test_maybe_fault_matching(monkeypatch):
    fired = []
    monkeypatch.setattr(fi, "_fire", lambda spec: fired.append(spec))
    monkeypatch.delenv(fi.ENV_VAR, raising=False)
    assert not fi.maybe_fault("step", 3)          # no fault requested
    monkeypatch.setenv(fi.ENV_VAR, "step@5")
    assert not fi.maybe_fault("step", 3)          # wrong step
    assert not fi.maybe_fault("ckpt_mid_write")   # wrong site
    assert fi.maybe_fault("step", 5)
    monkeypatch.setenv(fi.ENV_VAR, "step:sigterm")
    assert fi.maybe_fault("step", 0) and fi.maybe_fault("step", 9)
    assert len(fired) == 3


def test_sigterm_fault_drives_preemption_guard(monkeypatch):
    """The sigterm action returns to the caller with the guard flag set —
    the graceful-preemption path the train loop takes."""
    old = signal.getsignal(signal.SIGTERM)
    try:
        guard = PreemptionGuard(install=True)
        monkeypatch.setenv(fi.ENV_VAR, "step@2:sigterm")
        assert not fi.maybe_fault("step", 1)
        assert not guard.should_stop()
        assert fi.maybe_fault("step", 2)
        assert guard.should_stop()
    finally:
        signal.signal(signal.SIGTERM, old)


def test_run_subprocess_asserts_death_mode(tmp_path):
    code = ("from repro.runtime.fault_injection import maybe_fault\n"
            "maybe_fault('boom')\nprint('SURVIVED')")
    env = {"PYTHONPATH": "src"}
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = fi.run_subprocess(code, fi.FaultSpec("boom", action="exit"),
                          env=env, cwd=root)
    assert "SURVIVED" not in r.stdout
    # a run that survives its own crash test must fail the harness
    with pytest.raises(AssertionError):
        fi.run_subprocess(code, fi.FaultSpec("other_site", action="exit"),
                          env=env, cwd=root)
    # no fault: plain success asserted
    r = fi.run_subprocess("print('ok')", env=env, cwd=root)
    assert "ok" in r.stdout
