"""Layer-scope clipping (scope="layer": every trainable param path is its
own clip unit) and the streamed one-pass BK backward it unlocks.

Parity contract:
  * layer-scope grads match a vmap(grad) + hand-rolled per-unit clipping
    reference across BK and baseline modes (the scope axis is engine-wide,
    not a BK special case);
  * the streamed path (default) is BITWISE identical to the two-phase
    engine (REPRO_STREAM=0) when the fused kernel is off — streaming
    reorders the schedule, not the math;
  * with kernels on, the fused norm+clip+grad Pallas launch reassociates
    one reduction, so streamed-vs-two-phase is allclose at 1e-6;
  * plan_report marks every streamed tap with the engine-assigned "stream"
    store and ZERO held tape bytes — the one-pass claim, checkable without
    a profiler.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bk import DPConfig, bk_clipped_sum, monolithic_clipped_sum
from repro.core.engine import ALL_MODES, PrivacyEngine, make_grad_fn
from repro.core.policy import (ParamGroup, PrivacyPolicy, as_policy,
                               resolve_policy, with_scope)
from repro.core.tape import Tape
from repro.kernels import dispatch
from repro.models.mlp import MLP, MLPConfig
from repro.utils.tree import flatten

B = 8
BK = ("bk", "bk-mixghost", "bk-mixopt")


def _setup(bias=True):
    model = MLP(MLPConfig(d_in=12, width=16, depth=3, n_classes=5, bias=bias))
    params = model.init(jax.random.PRNGKey(0))
    batch = {
        "x": jax.random.normal(jax.random.PRNGKey(1), (B, 12)),
        "y": jax.random.randint(jax.random.PRNGKey(2), (B,), 0, 5),
    }
    return model, params, batch


def _layer_policy(mode, **kw):
    return with_scope(DPConfig(mode=mode, clipping="automatic", R=1.0,
                               sigma=0.0, **kw), "layer")


def _vmap_reference(model, params, batch, policy):
    """vmap per-sample grads + hand-rolled per-unit clipping (same oracle as
    test_policy, reused here because layer scope just makes more units)."""
    res = resolve_policy(policy, flatten(params))
    gfn = jax.grad(lambda p, s: model.apply(
        p, jax.tree_util.tree_map(lambda x: x[None], s), Tape(None))[0])
    per_g = flatten(jax.vmap(gfn, in_axes=(None, 0))(params, batch))
    norms, C = {}, {}
    for unit in res.units:
        sq = sum(jnp.sum(jnp.square(per_g[p].reshape(B, -1)), axis=1)
                 for p in unit.paths)
        norms[unit.name] = jnp.sqrt(sq)
        C[unit.name] = unit.clip_fn()(norms[unit.name])
    out = {}
    for p, g in per_g.items():
        if p in res.frozen:
            out[p] = jnp.zeros(g.shape[1:], g.dtype)
        else:
            unit = res.units[res.unit_of[p]]
            out[p] = jnp.einsum("b...,b->...", g, C[unit.name]) / B
    return out, norms


def _assert_tree(got, want, *, bitwise=False, rtol=1e-5, atol=1e-6, msg=""):
    for k, v in flatten(want).items():
        g = np.asarray(flatten(got)[k])
        if bitwise:
            np.testing.assert_array_equal(g, np.asarray(v),
                                          err_msg=f"{msg} {k}")
        else:
            np.testing.assert_allclose(g, np.asarray(v), rtol=rtol,
                                       atol=atol, err_msg=f"{msg} {k}")


# ------------------------------------------------------------------ resolution
def test_with_scope_layer_one_unit_per_path():
    _, params, _ = _setup()
    res = resolve_policy(_layer_policy("bk"), flatten(params))
    paths = sorted(flatten(params))
    assert len(res.units) == len(paths)
    for u in res.units:
        assert len(u.paths) == 1
        assert u.name.endswith(":" + u.paths[0])
    # partition: every path in exactly one unit
    seen = sorted(p for u in res.units for p in u.paths)
    assert seen == paths


def test_with_scope_keeps_frozen_groups():
    _, params, _ = _setup()
    policy = with_scope(PrivacyPolicy(groups=(
        ParamGroup("frozen", r"l0/.*", trainable=False),
        ParamGroup("rest", ".*", R=1.0, scope="group"),
    ), mode="bk"), "layer")
    assert policy.groups[0].trainable is False
    assert policy.groups[0].scope != "layer" or not policy.groups[0].trainable
    res = resolve_policy(policy, flatten(params))
    assert all(p.startswith("l0/") for p in res.frozen)
    assert all(len(u.paths) == 1 for u in res.units)


# ---------------------------------------------------------------- correctness
@pytest.mark.parametrize("mode", ["bk", "bk-mixghost", "bk-mixopt", "opacus",
                                  "ghostclip"])
def test_layer_scope_matches_vmap_reference(mode):
    model, params, batch = _setup()
    policy = _layer_policy(mode)
    ref, ref_norms = _vmap_reference(model, params, batch, policy)
    got, aux = jax.jit(make_grad_fn(model.apply, policy))(
        params, batch, jax.random.PRNGKey(7))
    for name, n in ref_norms.items():
        np.testing.assert_allclose(aux["group_norms"][name], n,
                                   rtol=1e-5, atol=1e-6, err_msg=name)
    for p, g in sorted(flatten(got).items()):
        np.testing.assert_allclose(g, ref[p], rtol=1e-4, atol=1e-6,
                                   err_msg=f"{mode}:{p}")


@pytest.mark.parametrize("mode", BK)
def test_streamed_bitwise_vs_two_phase_without_kernels(mode, monkeypatch):
    """With the fused kernel off, streaming is an op-identical reordering of
    the two-phase engine: phase 2+3 fuse at the tap, same primitives, same
    order per tap -> bitwise."""
    model, params, batch = _setup()
    policy = _layer_policy(mode)
    monkeypatch.setenv("REPRO_KERNELS", "0")
    dispatch.clear_cache()
    try:
        fn = lambda: jax.jit(
            lambda p, b: bk_clipped_sum(model.apply, p, b, policy,
                                        rng=jax.random.PRNGKey(3)))(
                params, batch)
        monkeypatch.setenv("REPRO_STREAM", "1")
        got, _ = fn()
        monkeypatch.setenv("REPRO_STREAM", "0")
        two_phase, _ = fn()
        _assert_tree(got, two_phase, bitwise=True, msg=mode)
    finally:
        dispatch.clear_cache()


@pytest.mark.parametrize("mode", BK)
def test_streamed_close_vs_two_phase_with_kernels(mode, monkeypatch):
    """Kernels on (default): the fused norm+clip+grad launch computes the
    same quantities in one reduction order -> tight allclose."""
    model, params, batch = _setup()
    policy = _layer_policy(mode)
    dispatch.clear_cache()
    fn = lambda: jax.jit(
        lambda p, b: bk_clipped_sum(model.apply, p, b, policy,
                                    rng=jax.random.PRNGKey(3)))(params, batch)
    got, aux = fn()
    monkeypatch.setenv("REPRO_STREAM", "0")
    two_phase, taux = fn()
    _assert_tree(got, two_phase, rtol=1e-5, atol=1e-6, msg=mode)
    np.testing.assert_allclose(np.asarray(aux["per_sample_norms"]),
                               np.asarray(taux["per_sample_norms"]),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("mode", [m for m in ALL_MODES
                                  if m != "nonprivate"])
def test_layer_scope_across_all_modes(mode):
    """Every clipping mode accepts a layer-scope policy and agrees with the
    vmap reference under it — the scope axis is engine-wide, not a BK
    special case (nonprivate has no clipping, so no scope)."""
    model, params, batch = _setup()
    policy = _layer_policy(mode)
    ref, _ = _vmap_reference(model, params, batch, policy)
    got, _ = jax.jit(make_grad_fn(model.apply, policy))(
        params, batch, jax.random.PRNGKey(3))
    for p, g in sorted(flatten(got).items()):
        np.testing.assert_allclose(g, ref[p], rtol=1e-4, atol=1e-6,
                                   err_msg=f"{mode}:{p}")


# --------------------------------------------------------------- fused kernel
def test_fused_kernel_matches_einsum_reference():
    from repro.core.clipping import get_clip_fn
    from repro.kernels import ops as kops
    rng = jax.random.PRNGKey(0)
    a = jax.random.normal(rng, (B, 4, 24))
    ds = jax.random.normal(jax.random.fold_in(rng, 1), (B, 4, 10))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(rng, 2), (B,)))
    g_b = jnp.einsum("btd,btp->bdp", a, ds)
    sq_ref = jnp.sum(g_b.reshape(B, -1) ** 2, axis=1)
    clip = get_clip_fn("automatic", 1.0, gamma=0.01)
    c = clip(jnp.sqrt(sq_ref)) * w
    out_ref = jnp.einsum("bdp,b->dp", g_b, c)
    out, sq = kops.fused_clip_grad_mm(a, ds, w, "automatic", 1.0, 0.01)
    np.testing.assert_allclose(np.asarray(sq), np.asarray(sq_ref),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               rtol=1e-5, atol=1e-5)


def test_fused_kernel_stacked_layers():
    from repro.kernels import ops as kops
    L = 3
    rng = jax.random.PRNGKey(4)
    a = jax.random.normal(rng, (L, B, 4, 16))
    ds = jax.random.normal(jax.random.fold_in(rng, 1), (L, B, 4, 8))
    w = jnp.ones((B,))
    g_b = jnp.einsum("lbtd,lbtp->bldp", a, ds)
    sq_ref = jnp.sum(g_b.reshape(B, -1) ** 2, axis=1)
    c = jnp.minimum(1.0, 1.0 / jnp.maximum(jnp.sqrt(sq_ref), 1e-12))
    out_ref = jnp.einsum("bldp,b->ldp", g_b, c)
    out, sq = kops.fused_clip_grad_mm(a, ds, w, "abadi", 1.0, 0.01)
    np.testing.assert_allclose(np.asarray(sq), np.asarray(sq_ref),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               rtol=1e-5, atol=1e-5)


# -------------------------------------------------------------- observability
def test_plan_report_streams_layer_taps():
    """Acceptance: one forward + one backward — every streamed tap plans
    the engine-assigned 'stream' store with ZERO held tape bytes, and the
    fused plan participates for mm taps."""
    model, params, batch = _setup()
    report = PrivacyEngine(
        model.apply, _layer_policy("bk-mixopt")).kernel_report(params, batch)
    assert report
    held = 0
    for key, plans in report.items():
        assert plans["tape"].store == "stream", key
        assert plans["tape"].hold_bytes == 0, key
        held += plans["tape"].hold_bytes
        if key.endswith("#mm"):
            assert "fused" in plans, key
    assert held == 0


def test_plan_report_flat_scope_unchanged():
    """Flat scope never streams: report keeps the pre-layer-scope contract
    (no 'stream' store, no 'fused' entry)."""
    model, params, batch = _setup()
    report = PrivacyEngine(
        model.apply, DPConfig(mode="bk-mixopt")).kernel_report(params, batch)
    for key, plans in report.items():
        assert set(plans) == {"norm", "grad", "tape"}, key
        assert plans["tape"].store != "stream", key


def test_stream_env_kill_switch(monkeypatch):
    monkeypatch.setenv("REPRO_STREAM", "0")
    model, params, batch = _setup()
    report = PrivacyEngine(
        model.apply, _layer_policy("bk-mixopt")).kernel_report(params, batch)
    assert all(p["tape"].store != "stream" for p in report.values())


def test_stream_store_not_user_requestable():
    with pytest.raises(ValueError, match="tape"):
        ParamGroup("g", ".*", tape="stream")
    with pytest.raises(ValueError, match="tape_policy"):
        PrivacyPolicy(groups=(ParamGroup("all", ".*"),),
                      tape_policy="stream")


# ------------------------------------------------------- scan-stacked models
def _smoke():
    from repro.configs.registry import build, smoke_config
    cfg = smoke_config("qwen2-1.5b").with_(dtype="float32",
                                           param_dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16),
                                          0, cfg.vocab)}
    return model, params, batch


def test_layer_scope_on_scanned_transformer():
    """Stacked taps (scan body, '.s' keys) stream too: layer-scope grads on
    a real transformer match the monolithic oracle."""
    model, params, batch = _smoke()
    policy = _layer_policy("bk-mixopt")
    ref, _ = jax.jit(
        lambda p, b: monolithic_clipped_sum(model.apply, p, b,
                                            policy))(params, batch)
    got, _ = jax.jit(
        lambda p, b: bk_clipped_sum(model.apply, p, b, policy,
                                    rng=jax.random.PRNGKey(3)))(params, batch)
    _assert_tree(got, ref, rtol=1e-4, atol=1e-5, msg="scan")


def test_scan_group_tape_override_is_scope_relative():
    """Satellite: ParamGroup.tape matches taps INSIDE scan bodies — the
    stacked '<prefix><key>.s' tap resolves through the group of its weight
    path, so a group pinning blocks/mlp to bf16 shows up on the stacked
    tap while everything else keeps the policy default."""
    model, params, batch = _smoke()
    policy = PrivacyPolicy(groups=(
        ParamGroup("mlp", r"blocks/mlp/.*", R=1.0, scope="group",
                   tape="bf16"),
        ParamGroup("rest", ".*", R=1.0, scope="group"),
    ), mode="bk", tape_policy="native")
    report = PrivacyEngine(model.apply, policy).kernel_report(params, batch)
    stores = {k: p["tape"].store for k, p in report.items()}
    mlp_keys = [k for k in stores if k.startswith("blocks/mlp/")]
    assert mlp_keys, stores
    assert all(stores[k] == "bf16" for k in mlp_keys), stores
    assert all(s == "native" for k, s in stores.items()
               if not k.startswith("blocks/mlp/")), stores
    ref, _ = jax.jit(
        lambda p, b: monolithic_clipped_sum(model.apply, p, b,
                                            with_scope(policy, "group")))(
        params, batch)
    got, _ = jax.jit(
        lambda p, b: bk_clipped_sum(model.apply, p, b, policy))(params, batch)
    _assert_tree(got, ref, rtol=1e-2, atol=5e-3, msg="scan-override")


# ------------------------------------------------------------------ training
def test_train_loop_layer_vs_flat():
    """Seeded 12-step run: layer scope trains (loss decreases) and lands
    within tolerance of the flat-scope run — scope changes the clipping
    geometry, not the optimization."""
    from repro.configs.base import TrainConfig
    from repro.configs.registry import smoke_config
    from repro.launch.train import train

    cfg = smoke_config("qwen2-1.5b").with_(dtype="float32",
                                           param_dtype="float32")
    dp = DPConfig(mode="bk-mixopt", clipping="automatic", sigma=0.3)
    tc = TrainConfig(global_batch=8, microbatch=4, seq_len=16, steps=12,
                     lr=2e-3, policy="")
    _, flat_losses = train(cfg, tc, dp, log=lambda *a: None)
    import dataclasses
    tc_layer = dataclasses.replace(tc, clipping_scope="layer")
    _, layer_losses = train(cfg, tc_layer, dp, log=lambda *a: None)
    assert len(layer_losses) == 12
    assert np.mean(layer_losses[-3:]) < np.mean(layer_losses[:3])
    assert abs(np.mean(layer_losses[-3:]) - np.mean(flat_losses[-3:])) \
        < 0.25 * np.mean(flat_losses[-3:])
