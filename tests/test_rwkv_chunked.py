"""wkv6_chunked == wkv6_ref (the sequential oracle), incl. psp-batched u."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.rwkv6 import wkv6_chunked, wkv6_ref


@pytest.mark.parametrize("B,T,H,h,chunk", [(2, 64, 2, 8, 16), (1, 100, 3, 16, 32),
                                           (2, 33, 2, 8, 32)])
def test_chunked_matches_ref(B, T, H, h, chunk):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (B, T, H, h))
    k = jax.random.normal(ks[1], (B, T, H, h))
    v = jax.random.normal(ks[2], (B, T, H, h))
    w = jax.random.uniform(ks[3], (B, T, H, h), minval=0.5, maxval=0.999)
    u = jax.random.normal(ks[4], (H, h)) * 0.5
    np.testing.assert_allclose(wkv6_chunked(r, k, v, w, u, chunk),
                               wkv6_ref(r, k, v, w, u), rtol=2e-4, atol=2e-4)


def test_chunked_batched_u():
    B, T, H, h = 2, 64, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, h)) for i in range(3))
    w = jax.random.uniform(ks[3], (B, T, H, h), minval=0.6, maxval=0.99)
    u = jax.random.normal(ks[4], (B, H, h)) * 0.5  # psp layout
    np.testing.assert_allclose(wkv6_chunked(r, k, v, w, u),
                               wkv6_ref(r, k, v, w, u), rtol=2e-4, atol=2e-4)


def test_chunked_grads_match():
    B, T, H, h = 1, 48, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, h)) for i in range(3))
    w = jax.random.uniform(ks[3], (B, T, H, h), minval=0.6, maxval=0.99)
    u = jax.random.normal(ks[4], (H, h)) * 0.5
    f1 = jax.grad(lambda kk: jnp.sum(jnp.square(wkv6_chunked(r, kk, v, w, u, 16))))
    f2 = jax.grad(lambda kk: jnp.sum(jnp.square(wkv6_ref(r, kk, v, w, u))))
    np.testing.assert_allclose(f1(k), f2(k), rtol=2e-3, atol=2e-3)
