"""DP-FTRL subsystem + heterogeneous per-group noise tests:

  * FTRL-vs-SGD prefix-sum equivalence at sigma=0
  * tree-aggregation epoch restarts: telescoping, fresh trees, completion
    (honest-restart) variance correction
  * get_mechanism depth pass-through regression (a depth=0 default must not
    clobber the tree's own 30)
  * per-group sigma: noise scales per unit, joint RDP bound vs the flat
    single-sigma bound (equality at scale 1, monotone in the scales)
  * policy-aware plan_cell: the dryrun grid plans the arch's registered
    group-wise policy, not a flat DPConfig
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accounting import compute_epsilon, effective_sigma
from repro.core.noise import (GaussianMechanism, TreeAggregationMechanism,
                              add_noise, get_mechanism, next_pow2)
from repro.core.policy import (ParamGroup, PrivacyPolicy, finalize_noise,
                               resolve_policy)
from repro.optim.optimizers import make_optimizer


# ------------------------------------------------------------------ mechanism
def test_get_mechanism_depth_passthrough():
    """Regression: the former depth=0 default silently built a depth-0 tree
    (prefix_noise over range(0) — NO noise at all)."""
    assert get_mechanism("tree").depth == 30
    assert get_mechanism("tree", depth=0).depth == 30
    assert get_mechanism("tree", depth=7).depth == 7
    # a depth-0 tree would return zeros from prefix_noise — make sure the
    # default actually draws noise
    m = get_mechanism("tree")
    z = m.prefix_noise("p", (8,), 5)
    assert float(jnp.sum(jnp.abs(z))) > 0.0


def test_tree_restart_fresh_epochs_and_telescoping():
    E = 6
    m = TreeAggregationMechanism(seed=3, depth=6, restart_every=E)
    g = {"p": jnp.zeros((16,))}
    acc = jnp.zeros((16,))
    for step in range(E):
        acc = acc + m.add(g, None, 1.0, 1.0, 1.0, step=step)["p"]
    # increments telescope to the epoch-local prefix N_0(E)
    np.testing.assert_allclose(np.asarray(acc),
                               np.asarray(m.prefix_noise("p", (16,), E,
                                                         epoch=0)), rtol=1e-6)
    # first step of epoch 1 is the FRESH tree's N_1(1), not a diff vs epoch 0
    inc = m.add(g, None, 1.0, 1.0, 1.0, step=E)["p"]
    np.testing.assert_allclose(np.asarray(inc),
                               np.asarray(m.prefix_noise("p", (16,), 1,
                                                         epoch=1)), rtol=1e-6)
    # epochs draw independent node noise
    n0 = m.prefix_noise("p", (16,), 1, epoch=0)
    assert float(jnp.max(jnp.abs(n0 - inc))) > 1e-3


def test_tree_completion_variance_correction():
    """With completion the epoch's accumulated noise is the completed
    prefix N(next_pow2(E)) — ONE root-path node (popcount = 1) instead of
    popcount(E) nodes — so the restart rebases on minimum-variance noise."""
    E = 6  # popcount(6) = 2 nodes uncompleted; next_pow2(6) = 8 -> 1 node
    assert next_pow2(E) == 8
    m = TreeAggregationMechanism(seed=0, depth=5, restart_every=E,
                                 completion=True)
    g = {"p": jnp.zeros((4096,))}
    acc = jnp.zeros((4096,))
    for step in range(E):
        acc = acc + m.add(g, None, 1.0, 1.0, 1.0, step=step)["p"]
    want = m.prefix_noise("p", (4096,), 8, epoch=0)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
    # single-node variance ~1 (vs popcount(6)=2 without completion)
    v_completed = float(jnp.var(acc))
    m2 = TreeAggregationMechanism(seed=0, depth=5, restart_every=E)
    acc2 = jnp.zeros((4096,))
    for step in range(E):
        acc2 = acc2 + m2.add(g, None, 1.0, 1.0, 1.0, step=step)["p"]
    v_plain = float(jnp.var(acc2))
    assert v_completed == pytest.approx(1.0, rel=0.15)
    assert v_plain == pytest.approx(2.0, rel=0.15)


def test_tree_completion_requires_restarts():
    with pytest.raises(ValueError):
        TreeAggregationMechanism(completion=True)


def test_tree_rejects_steps_past_horizon():
    """Past 2^depth - 1 the prefix collapses (every level index even) and
    increments would SUBTRACT released noise — must raise, not under-noise."""
    m = TreeAggregationMechanism(seed=0, depth=3)
    g = {"p": jnp.zeros((4,))}
    m.add(g, None, 1.0, 1.0, 1.0, step=6)           # t = 7 = horizon: fine
    with pytest.raises(ValueError, match="horizon"):
        m.add(g, None, 1.0, 1.0, 1.0, step=7)       # t = 8 > 2^3 - 1
    with pytest.raises(ValueError, match="horizon"):
        m.add(g, None, 1.0, 1.0, 1.0, step=np.int64(7))  # numpy ints too


def test_train_honors_policy_configured_tree_noise():
    """A policy that already configures tree noise keeps its knobs (no
    silent override); the FTRL anchor restarts at the policy's boundary;
    conflicting boundaries raise."""
    from repro.configs.base import TrainConfig
    from repro.configs.registry import smoke_config
    from repro.launch.train import train

    cfg = smoke_config("qwen2-1.5b").with_(dtype="float32",
                                           param_dtype="float32")
    pol = PrivacyPolicy(groups=(ParamGroup("all", ".*"),), mode="bk",
                        sigma=0.3, noise="tree", noise_depth=4,
                        noise_restart_every=2, noise_completion=True)
    logs = []
    tc = TrainConfig(global_batch=4, seq_len=16, steps=5, lr=1e-3,
                     lr_schedule="constant", optimizer="ftrl")
    _, losses = train(cfg, tc, pol, log=logs.append)
    assert np.all(np.isfinite(losses))
    assert any("restart_every=2" in str(l) and "depth=4" in str(l)
               and "completion=True" in str(l) for l in logs), logs

    import dataclasses
    with pytest.raises(ValueError, match="restart together"):
        train(cfg, dataclasses.replace(tc, restart_every=3), pol,
              log=lambda *a: None)


def test_train_rejects_undersized_tree_depth():
    """Traced steps can't hit the mechanism's concrete-step horizon guard,
    so the driver must validate depth-vs-steps upfront for ANY optimizer."""
    from repro.configs.base import TrainConfig
    from repro.configs.registry import smoke_config
    from repro.launch.train import train

    cfg = smoke_config("qwen2-1.5b").with_(dtype="float32",
                                           param_dtype="float32")
    pol = PrivacyPolicy(groups=(ParamGroup("all", ".*"),), mode="bk",
                        sigma=0.3, noise="tree", noise_depth=3)
    tc = TrainConfig(global_batch=4, seq_len=16, steps=20,
                     optimizer="adamw")
    with pytest.raises(ValueError, match="noise_depth"):
        train(cfg, tc, pol, log=lambda *a: None)


def test_train_rejects_ftrl_knobs_on_other_optimizers():
    from repro.configs.base import TrainConfig
    from repro.configs.registry import smoke_config
    from repro.core.bk import DPConfig
    from repro.launch.train import train

    cfg = smoke_config("qwen2-1.5b").with_(dtype="float32",
                                           param_dtype="float32")
    tc = TrainConfig(global_batch=4, seq_len=16, steps=2,
                     optimizer="adamw", restart_every=10)
    with pytest.raises(ValueError, match="ftrl"):
        train(cfg, tc, DPConfig(mode="bk", sigma=0.1), log=lambda *a: None)


def test_tree_traced_step_matches_python_step():
    m = TreeAggregationMechanism(seed=1, depth=4, restart_every=3,
                                 completion=True)
    g = {"p": jnp.zeros((8,))}
    f = jax.jit(lambda s: m.add(g, None, 1.0, 1.0, 1.0, step=s)["p"])
    for step in range(6):
        np.testing.assert_allclose(
            np.asarray(f(jnp.asarray(step))),
            np.asarray(m.add(g, None, 1.0, 1.0, 1.0, step=step)["p"]),
            rtol=1e-5)


# ----------------------------------------------------------------------- ftrl
def _quad_grads(key, n, d):
    """Deterministic gradient stream for optimizer-only tests."""
    return [jax.random.normal(jax.random.fold_in(key, i), (d,))
            for i in range(n)]


def test_ftrl_sgd_prefix_sum_equivalence():
    """sigma=0, momentum=0, constant lr: theta_t = theta_0 - lr * sum g_s is
    the SGD trajectory exactly (gradients evaluated at the same iterates)."""

    def loss(p, x):
        return jnp.sum((p["w"] @ x - 1.0) ** 2)

    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 4))}
    lr = lambda s: jnp.asarray(0.05, jnp.float32)
    ftrl = make_optimizer("ftrl", lr)
    sgd = make_optimizer("sgd", lr, momentum=0.0)
    pf, sf = params, ftrl.init(params)
    ps, ss = params, sgd.init(params)
    for i in range(7):
        x = jax.random.normal(jax.random.PRNGKey(i + 1), (4,))
        pf, sf = ftrl.update(jax.grad(loss)(pf, x), sf, pf, jnp.asarray(i))
        ps, ss = sgd.update(jax.grad(loss)(ps, x), ss, ps, jnp.asarray(i))
        np.testing.assert_allclose(np.asarray(pf["w"]), np.asarray(ps["w"]),
                                   rtol=1e-5, atol=1e-6)


def test_ftrl_restart_rebases_anchor():
    """After a restart at step E the iterate depends only on gradients seen
    SINCE the restart (prefix sum zeroed, anchor moved)."""
    E, d = 3, 5
    lr = lambda s: jnp.asarray(0.1, jnp.float32)
    opt = make_optimizer("ftrl", lr, restart_every=E)
    params = {"w": jnp.zeros((d,))}
    gs = _quad_grads(jax.random.PRNGKey(2), 2 * E, d)
    p, s = params, opt.init(params)
    for i, g in enumerate(gs):
        p, s = opt.update({"w": g}, s, p, jnp.asarray(i))
        if i == E - 1:
            anchor = p["w"]
    # steps E..2E-1: theta = anchor - lr * sum_{s>=E} g_s
    want = anchor - 0.1 * sum(gs[E:])
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_ftrl_momentum_matches_reference_recursion():
    beta, lr_v, d = 0.7, 0.05, 4
    opt = make_optimizer("ftrl", lambda s: jnp.asarray(lr_v, jnp.float32),
                         momentum=beta)
    params = {"w": jnp.zeros((d,))}
    gs = _quad_grads(jax.random.PRNGKey(5), 5, d)
    p, s = params, opt.init(params)
    S = jnp.zeros((d,))
    m = jnp.zeros((d,))
    for i, g in enumerate(gs):
        p, s = opt.update({"w": g}, s, p, jnp.asarray(i))
        S = S + g
        m = beta * m + S
        np.testing.assert_allclose(np.asarray(p["w"]),
                                   np.asarray(-lr_v * m),
                                   rtol=1e-5, atol=1e-6)


def test_ftrl_rejects_weight_decay():
    with pytest.raises(ValueError):
        make_optimizer("ftrl", lambda s: 0.1, weight_decay=0.01)


def test_ftrl_end_to_end_tree_noise_restarts():
    """The full train driver: --optimizer ftrl switches the policy to tree
    noise keyed off the optimizer's restart boundary; losses stay finite and
    the run completes across two restarts."""
    from repro.configs.base import TrainConfig
    from repro.configs.registry import smoke_config
    from repro.core.bk import DPConfig
    from repro.launch.train import train

    cfg = smoke_config("qwen2-1.5b").with_(dtype="float32",
                                           param_dtype="float32")
    tc = TrainConfig(global_batch=4, seq_len=16, steps=7, lr=1e-3,
                     lr_schedule="constant", optimizer="ftrl",
                     ftrl_momentum=0.5, restart_every=3,
                     tree_completion=True)
    dp = DPConfig(mode="bk", clipping="automatic", sigma=0.4)
    _, losses = train(cfg, tc, dp, log=lambda *a: None)
    assert len(losses) == 7
    assert np.all(np.isfinite(losses))


# ------------------------------------------------------- heterogeneous noise
def _two_group_policy(scale_a=1.0, scale_b=1.0, sigma=1.2):
    return PrivacyPolicy(groups=(
        ParamGroup("a", "x", R=0.5, scope="group", sigma_scale=scale_a),
        ParamGroup("b", ".*", R=1.0, scope="group", sigma_scale=scale_b),
    ), sigma=sigma)


def test_heterogeneous_epsilon_matches_flat_at_unit_scales():
    res = resolve_policy(_two_group_policy(), ["x/w", "y/w"])
    ms = res.noise_multipliers()
    assert effective_sigma(ms) == pytest.approx(1.2, rel=1e-12)
    e_flat = compute_epsilon(1.2, 0.02, 500, 1e-5)
    e_joint = compute_epsilon(ms, 0.02, 500, 1e-5)
    assert e_joint == pytest.approx(e_flat, rel=1e-9)


def test_heterogeneous_epsilon_monotone_in_scales():
    eps = []
    for s in (0.5, 0.8, 1.0, 1.5, 3.0):
        res = resolve_policy(_two_group_policy(scale_a=s), ["x/w", "y/w"])
        eps.append(compute_epsilon(res.noise_multipliers(), 0.02, 500, 1e-5))
    assert all(a >= b for a, b in zip(eps, eps[1:]))
    # scales >= 1 everywhere -> joint bound <= the flat-sigma bound
    e_flat = compute_epsilon(1.2, 0.02, 500, 1e-5)
    res_up = resolve_policy(_two_group_policy(scale_a=2.0, scale_b=1.0),
                            ["x/w", "y/w"])
    assert compute_epsilon(res_up.noise_multipliers(), 0.02, 500,
                           1e-5) <= e_flat + 1e-9


def test_finalize_noise_per_group_scales():
    """Heterogeneous policies scale each unit's leaves by
    sigma_scale_u * S; homogeneous policies keep the exact pre-existing
    flat draw (same rng path-splits, same std)."""
    pol = _two_group_policy(scale_a=0.25, scale_b=2.0, sigma=0.7)
    res = resolve_policy(pol, ["x/w", "y/w"])
    sums = {"x/w": jnp.zeros((32,)), "y/w": jnp.zeros((32,))}
    rng = jax.random.PRNGKey(9)
    out = finalize_noise(pol, res, sums, rng, 1.0)
    S = res.sensitivity
    ref_a = add_noise({"x/w": sums["x/w"]}, rng, 0.7, 0.25 * S, 1.0)["x/w"]
    ref_b = add_noise({"y/w": sums["y/w"]}, rng, 0.7, 2.0 * S, 1.0)["y/w"]
    np.testing.assert_allclose(np.asarray(out["x/w"]), np.asarray(ref_a))
    np.testing.assert_allclose(np.asarray(out["y/w"]), np.asarray(ref_b))

    # homogeneous: bitwise-identical to the composed-sensitivity float path
    pol0 = _two_group_policy(sigma=0.7)
    res0 = resolve_policy(pol0, ["x/w", "y/w"])
    out0 = finalize_noise(pol0, res0, sums, rng, 1.0)
    ref0 = GaussianMechanism().add(sums, rng, 0.7, res0.sensitivity, 1.0)
    for k in sums:
        np.testing.assert_allclose(np.asarray(out0[k]), np.asarray(ref0[k]))


def test_flat_groups_must_agree_on_sigma_scale():
    pol = PrivacyPolicy(groups=(
        ParamGroup("a", "x", scope="flat", sigma_scale=2.0),
        ParamGroup("b", ".*", scope="flat"),
    ), sigma=1.0)
    with pytest.raises(ValueError, match="sigma_scale"):
        resolve_policy(pol, ["x/w", "y/w"])


def test_sigma_scale_must_be_positive():
    with pytest.raises(ValueError, match="sigma_scale"):
        ParamGroup("a", ".*", sigma_scale=0.0)


def test_policy_restart_knobs_require_tree_noise():
    """Gaussian noise has no tree: restart/completion knobs on a gaussian
    policy would be silently ignored — must raise instead."""
    with pytest.raises(ValueError, match="noise='tree'"):
        PrivacyPolicy(groups=(ParamGroup("all", ".*"),),
                      noise_restart_every=10)
    with pytest.raises(ValueError, match="noise='tree'"):
        PrivacyPolicy(groups=(ParamGroup("all", ".*"),),
                      noise="gaussian", noise_completion=True)
    # tree accepts them
    PrivacyPolicy(groups=(ParamGroup("all", ".*"),), noise="tree",
                  noise_restart_every=10, noise_completion=True)


# ------------------------------------------------------------------ plan_cell
def test_plan_cell_threads_registered_policy(monkeypatch):
    """The dryrun grid plans the arch's registered group-wise policy (and
    its extra per-unit book-keeping) instead of a flat DPConfig."""
    from unittest import mock

    from repro.configs import registry
    from repro.configs.base import SHAPES, ShapeConfig
    from repro.core.bk import DPConfig
    from repro.launch import steps as steps_mod

    small = registry.smoke_config("deepseek-moe-16b").with_(
        name="deepseek-moe-16b", remat=False, attn_chunk=0)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mock.patch.object(steps_mod, "get_config", lambda n: small), \
         mock.patch.dict(SHAPES, {"train_4k": ShapeConfig("train_4k", 16, 8,
                                                          "train")}), \
         mock.patch.dict(steps_mod.TRAIN_MICROBATCH,
                         {"deepseek-moe-16b": 4}):
        plan_pol = steps_mod.plan_cell("deepseek-moe-16b", "train_4k", mesh)
        assert "policy=deepseek-moe-16b(3g)" in plan_pol.note
        plan_flat = steps_mod.plan_cell(
            "deepseek-moe-16b", "train_4k", mesh,
            dp=DPConfig(mode="bk-mixopt", clipping="automatic", sigma=1.0))
        assert "policy=" not in plan_flat.note
        co_pol = plan_pol.lower().compile()
        co_flat = plan_flat.lower().compile()
        ma_pol, ma_flat = co_pol.memory_analysis(), co_flat.memory_analysis()
        assert ma_pol.argument_size_in_bytes == ma_flat.argument_size_in_bytes
        # group-wise clipping runs 3 per-sample norm accumulators + clip
        # factors where flat runs one: the programs must actually differ
        assert co_pol.as_text() != co_flat.as_text()
        assert ma_pol.temp_size_in_bytes > 0
