"""Per-architecture smoke tests: reduced same-family config, one forward /
BK train gradient / decode step on CPU. Output shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import build, get_config, list_archs, smoke_config
from repro.core.bk import DPConfig
from repro.core.engine import make_grad_fn
from repro.core.tape import Tape
from repro.data.synthetic import make_batch
from repro.utils.tree import flatten

ARCHS = list_archs()
B, T = 2, 16


def _finite(tree):
    for p, v in flatten(tree).items():
        assert np.all(np.isfinite(np.asarray(v, np.float32))), p


@pytest.fixture(scope="module")
def built(request):
    return {}


def _get(arch):
    cfg = smoke_config(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, B, T, seed=1)
    return cfg, model, params, batch


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10
    for a in ARCHS:
        cfg = get_config(a)
        assert cfg.name == a


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_losses(arch):
    cfg, model, params, batch = _get(arch)
    losses = model.apply(params, batch, Tape(None))
    assert losses.shape == (B,)
    assert np.all(np.isfinite(np.asarray(losses)))


@pytest.mark.parametrize("arch", ARCHS)
def test_bk_train_grad(arch):
    cfg, model, params, batch = _get(arch)
    fn = jax.jit(make_grad_fn(model.apply, DPConfig(mode="bk", sigma=0.1)))
    grads, aux = fn(params, batch, jax.random.PRNGKey(2))
    assert jax.tree_util.tree_structure(grads) == jax.tree_util.tree_structure(params)
    _finite(grads)
    assert np.all(np.asarray(aux["per_sample_norms"]) > 0)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg, model, params, batch = _get(arch)
    S = 32
    cache = model.init_cache(B, S)
    if cfg.family == "encdec":
        cache = model.init_cache(B, S, Tf=T)
        cache = model.prefill_cross(params, batch["frames"], cache)
    tokens = jnp.zeros((B,), jnp.int32)
    logits, new_cache = jax.jit(model.decode_step)(params, cache, tokens,
                                                   jnp.asarray(7, jnp.int32))
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert jax.tree_util.tree_structure(new_cache) == jax.tree_util.tree_structure(cache)


@pytest.mark.parametrize("arch", ARCHS)
def test_bk_equals_opacus_per_arch(arch):
    """The tap machinery is exact for every model family (f32 to isolate
    math from bf16 rounding)."""
    cfg = smoke_config(arch).with_(dtype="float32", param_dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, B, T, seed=1)
    ref, ra = make_grad_fn(model.apply, DPConfig(mode="opacus"))(
        params, batch, jax.random.PRNGKey(3))
    got, ga = make_grad_fn(model.apply, DPConfig(mode="bk-mixopt"))(
        params, batch, jax.random.PRNGKey(3))
    np.testing.assert_allclose(ga["per_sample_norms"], ra["per_sample_norms"],
                               rtol=2e-4, atol=1e-5)
    for (p, g), (_, r) in zip(sorted(flatten(got).items()),
                              sorted(flatten(ref).items())):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=2e-3, atol=2e-5, err_msg=f"{arch}:{p}")
