"""Kernel-vs-reference parity: every fused Pallas kernel against its pure-jnp
reference (repro.core.ghost), sweeping odd / non-multiple-of-block shapes,
bf16 inputs, and stacked (L,B,T,d) records. Acceptance bar: <= 1e-3 relative
error vs the f32 einsum reference (bf16 inputs get a looser bar — the MXU
accumulates in f32 on both paths but the 8-bit mantissa inputs differ)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ghost
from repro.kernels import dispatch, ops

F32 = jnp.float32
TOL = dict(rtol=1e-3, atol=1e-4)
# the jnp reference casts C to the record dtype (bf16) before the einsum,
# the kernel keeps it f32 — the kernel is the *more* accurate side
TOL_BF16 = dict(rtol=5e-2, atol=2e-2)


def _mk(shape, dtype=F32, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape,
                             F32).astype(dtype)


def _tol(dtype):
    return TOL if dtype == F32 else TOL_BF16


# odd T / d / p, non-multiples of every block size used below
MM_SHAPES = [
    (1, 2, 7, 5, 9),        # tiny, everything < block
    (1, 3, 33, 17, 23),     # odd, T % bt != 0
    (2, 2, 50, 24, 40),     # stacked, T % bt != 0
    (3, 2, 64, 31, 13),     # stacked, odd d/p
]
DTYPES = [F32, jnp.bfloat16]


@pytest.mark.parametrize("L,B,T,d,p", MM_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_ghost_norm_mm_parity(L, B, T, d, p, dtype):
    a, ds = _mk((L, B, T, d), dtype), _mk((L, B, T, p), dtype, 1)
    want = ghost.sq_norm_mm_ghost(a, ds)
    got = ops.ghost_norm_mm(a, ds, block_t=16)
    np.testing.assert_allclose(got, want, **_tol(dtype))


@pytest.mark.parametrize("L,B,T,d,p", MM_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_direct_norm_mm_parity(L, B, T, d, p, dtype):
    a, ds = _mk((L, B, T, d), dtype), _mk((L, B, T, p), dtype, 1)
    want = ghost.sq_norm_mm_direct(a, ds)
    got = ops.direct_norm_mm(a, ds, block_d=16, block_p=16)
    np.testing.assert_allclose(got, want, **_tol(dtype))


@pytest.mark.parametrize("L,B,T,d,p", MM_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_clipped_grad_mm_parity(L, B, T, d, p, dtype):
    a, ds = _mk((L, B, T, d), dtype), _mk((L, B, T, p), dtype, 1)
    C = jnp.abs(_mk((B,), F32, 2)) + 0.1
    want = ghost.weighted_grad_mm(a, C, ds, F32)
    got = ops.clipped_grad_mm(a, C, ds, block_d=16, block_p=16)
    assert got.shape == (L, d, p)
    np.testing.assert_allclose(got, want, **_tol(dtype))


def test_mm_kernels_unstacked_equal_stacked():
    a, ds = _mk((1, 2, 33, 17)), _mk((1, 2, 33, 23), seed=1)
    C = jnp.abs(_mk((2,), F32, 2)) + 0.1
    np.testing.assert_allclose(ops.ghost_norm_mm(a[0], ds[0], block_t=16),
                               ops.ghost_norm_mm(a, ds, block_t=16), rtol=1e-6)
    np.testing.assert_allclose(
        ops.clipped_grad_mm(a[0], C, ds[0], block_d=16, block_p=16),
        ops.clipped_grad_mm(a, C, ds, block_d=16, block_p=16)[0], rtol=1e-6)


# --------------------------------------------------------------------- emb
EMB_SHAPES = [(1, 2, 9, 6, 11), (2, 3, 33, 16, 50), (3, 2, 50, 24, 37)]


@pytest.mark.parametrize("L,B,T,d,V", EMB_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_emb_ghost_norm_parity(L, B, T, d, V, dtype):
    ids = jax.random.randint(jax.random.PRNGKey(3), (L, B, T), 0, V)
    ds = _mk((L, B, T, d), dtype, 1)
    want = ghost.sq_norm_emb(ids, ds)
    got = ops.ghost_norm_emb(ids, ds, block_t=16)
    np.testing.assert_allclose(got, want, **_tol(dtype))


@pytest.mark.parametrize("L,B,T,d,V", EMB_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_emb_clipped_grad_parity(L, B, T, d, V, dtype):
    ids = jax.random.randint(jax.random.PRNGKey(3), (L, B, T), 0, V)
    ds = _mk((L, B, T, d), dtype, 1)
    C = jnp.abs(_mk((B,), F32, 2)) + 0.1
    want = ghost.weighted_grad_emb(ids, C, ds, V, F32)
    got = ops.clipped_grad_emb(ids, C, ds, V, block_v=16)
    assert got.shape == (L, V, d)
    np.testing.assert_allclose(got, want, **_tol(dtype))


def test_emb_grad_oob_ids_dropped_consistently():
    """Out-of-range ids (pad/sentinel) must be DROPPED by both paths — the
    stacked jnp scatter must not fold layer l's OOB id into layer l+1."""
    L, B, T, d, V = 2, 2, 5, 4, 4
    ids = jnp.array([[[0, 4, 1, -1, 2]] * B, [[1, 2, 0, 3, 4]] * B])
    ds = _mk((L, B, T, d), seed=1)
    C = jnp.ones((B,), F32)
    got_jnp = ghost.weighted_grad_emb(ids, C, ds, V, F32)
    got_kern = ops.clipped_grad_emb(ids, C, ds, V, block_v=4)
    # oracle: per-layer scatter of only the in-range rows (note plain
    # .at[].add would WRAP negative ids to the last vocab row — both real
    # paths must drop them instead)
    valid = (ids >= 0) & (ids < V)
    wm = ds * valid[..., None]
    idc = jnp.clip(ids, 0, V - 1)
    want = jnp.stack([
        jnp.zeros((V, d), F32).at[idc[l].reshape(-1)].add(
            wm[l].reshape(-1, d)) for l in range(L)])
    np.testing.assert_allclose(got_jnp, want, **TOL)
    np.testing.assert_allclose(got_kern, want, **TOL)


def test_emb_kernels_unstacked():
    V = 21
    ids = jax.random.randint(jax.random.PRNGKey(3), (2, 17), 0, V)
    ds = _mk((2, 17, 8), seed=1)
    C = jnp.abs(_mk((2,), F32, 2)) + 0.1
    np.testing.assert_allclose(ops.ghost_norm_emb(ids, ds, block_t=8),
                               ghost.sq_norm_emb(ids, ds), **TOL)
    np.testing.assert_allclose(ops.clipped_grad_emb(ids, C, ds, V, block_v=8),
                               ghost.weighted_grad_emb(ids, C, ds, V, F32),
                               **TOL)


# --------------------------------------------------------------------- moe
MOE_SHAPES = [(1, 2, 3, 5, 12, 20), (2, 2, 4, 7, 9, 13), (2, 3, 2, 16, 24, 8)]


def _moe(L, B, E, C, d, p, dtype):
    a = _mk((L, B, E, C, d), dtype)
    mask = (jax.random.uniform(jax.random.PRNGKey(4),
                               (L, B, E, C)) > 0.3).astype(F32)
    ds = _mk((L, B, E, C, p), dtype, 1)
    return {"a": a, "mask": mask}, ds


@pytest.mark.parametrize("L,B,E,C,d,p", MOE_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_moe_ghost_norm_parity(L, B, E, C, d, p, dtype):
    rec, ds = _moe(L, B, E, C, d, p, dtype)
    want = ghost.sq_norm_moe_ghost(rec, ds)
    got = ops.ghost_norm_moe(rec, ds)
    np.testing.assert_allclose(got, want, **_tol(dtype))


@pytest.mark.parametrize("L,B,E,C,d,p", MOE_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_moe_direct_norm_parity(L, B, E, C, d, p, dtype):
    rec, ds = _moe(L, B, E, C, d, p, dtype)
    want = ghost.sq_norm_moe_direct(rec, ds)
    got = ops.direct_norm_moe(rec, ds, block_d=8, block_p=8)
    np.testing.assert_allclose(got, want, **_tol(dtype))


@pytest.mark.parametrize("L,B,E,C,d,p", MOE_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_moe_clipped_grad_parity(L, B, E, C, d, p, dtype):
    rec, ds = _moe(L, B, E, C, d, p, dtype)
    Cw = jnp.abs(_mk((B,), F32, 2)) + 0.1
    want = ghost.weighted_grad_moe(rec, Cw, ds, F32)
    got = ops.clipped_grad_moe(rec, Cw, ds, block_d=8, block_p=8)
    assert got.shape == (L, E, d, p)
    np.testing.assert_allclose(got, want, **_tol(dtype))


def test_moe_ghost_equals_direct_kernels():
    rec, ds = _moe(2, 2, 3, 6, 10, 14, F32)
    np.testing.assert_allclose(ops.ghost_norm_moe(rec, ds),
                               ops.direct_norm_moe(rec, ds, block_d=8,
                                                   block_p=8), rtol=1e-4)


# ---------------------------------------------------------------- dispatch
def test_dispatch_prefers_kernels_for_real_shapes():
    for kind, a_shape, ds_shape in [
            ("mm", (2, 8, 128, 64), (2, 8, 128, 64)),
            ("emb", (2, 8, 128), (2, 8, 128, 64)),
            ("moe", (2, 8, 4, 32, 64), (2, 8, 4, 32, 48))]:
        plan = dispatch.norm_plan(kind, a_shape, ds_shape, "bk")
        assert plan.impl == "kernel", (kind, plan)
        assert plan.method == "ghost"
        gplan = dispatch.grad_plan(kind, a_shape, ds_shape, vocab=512)
        assert gplan.impl == "kernel", (kind, gplan)


def test_dispatch_degenerate_records_stay_jnp():
    # MLP-style T=1 records: the Gram intermediate is one scalar per sample;
    # a kernel launch cannot pay for itself
    plan = dispatch.norm_plan("mm", (8, 1, 16), (8, 1, 16), "bk")
    assert plan.impl == "jnp"


def test_dispatch_blocks_respect_vmem_budget():
    bt = dispatch.block_t_ghost(4096, 4096, 4096)
    assert 4 * (2 * bt * 8192 + 3 * bt * bt) <= dispatch.VMEM_BUDGET
    bd, bp = dispatch.block_dp(4096, 8192, 8192)
    assert 4 * (4096 * (bd + bp) + bd * bp) <= dispatch.VMEM_BUDGET
    bv = dispatch.block_v(1024, 768, 50257)
    assert 4 * (1024 * bv + bv * 768 + 1024 * 768) <= dispatch.VMEM_BUDGET


def test_dispatch_layerwise_rule_matches_ghost_module():
    # long-T conv-style record -> direct; short-T wide layer -> ghost
    assert dispatch.norm_plan("mm", (4, 4096, 32, 32),
                              (4, 4096, 32, 64), "bk-mixghost").method == "direct"
    assert dispatch.norm_plan("mm", (4, 128, 256, 1024),
                              (4, 128, 256, 1024), "bk-mixghost").method == "ghost"


def test_mixopt_cache_survives_kernel_default():
    """bk-mixopt's phase-3 reuse of instantiated per-sample grads (paper
    Sec 3.3) must still engage with use_kernels=True for small direct-chosen
    records."""
    from repro.core.bk import record_sq_norm
    # direct-favored shape: 2T^2 > pd
    a, ds = _mk((2, 33, 8)), _mk((2, 33, 4), seed=1)
    _, cached = record_sq_norm("x#mm", a, ds, "bk-mixopt", use_kernels=True)
    assert cached is not None and cached.shape == (2, 8, 4)


def test_kernel_report_honors_use_kernels():
    from repro.core.bk import DPConfig
    from repro.core.engine import PrivacyEngine
    from repro.models.mlp import MLP, MLPConfig

    model = MLP(MLPConfig(d_in=8, width=256, depth=1, n_classes=4))
    params = model.init(jax.random.PRNGKey(0))
    batch = {"x": _mk((4, 8)), "y": jnp.zeros((4,), jnp.int32)}
    on = PrivacyEngine(model.apply, DPConfig(use_kernels=True))
    off = PrivacyEngine(model.apply, DPConfig(use_kernels=False))
    rep_on = on.kernel_report(params, batch)
    rep_off = off.kernel_report(params, batch)
    assert any(v["grad"].impl == "kernel" for v in rep_on.values())
    assert all(v["grad"].impl == "jnp" and v["norm"].impl == "jnp"
               for v in rep_off.values())


def test_engine_end_to_end_kernels_vs_jnp():
    """Full BK gradient, kernels on vs off, must agree (transformer smoke
    exercises mm + emb taps; odd seq length)."""
    from dataclasses import replace
    from repro.configs.registry import build, smoke_config
    from repro.core.bk import DPConfig
    from repro.core.engine import make_grad_fn

    from repro.data.synthetic import make_batch

    cfg = smoke_config("qwen2-1.5b").with_(dtype="float32",
                                           param_dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, B=4, T=13)
    dp = DPConfig(mode="bk", clipping="automatic", use_kernels=True)
    g1, a1 = make_grad_fn(model.apply, dp)(params, batch,
                                           jax.random.PRNGKey(7))
    g0, a0 = make_grad_fn(model.apply, replace(dp, use_kernels=False))(
        params, batch, jax.random.PRNGKey(7))
    np.testing.assert_allclose(a1["per_sample_norms"],
                               a0["per_sample_norms"], rtol=1e-3)
    from repro.utils.tree import flatten
    for (k, v1), (_, v0) in zip(sorted(flatten(g1).items()),
                                sorted(flatten(g0).items())):
        np.testing.assert_allclose(v1, v0, rtol=1e-3, atol=1e-4, err_msg=k)
