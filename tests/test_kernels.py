"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES_MM = [(2, 16, 8, 12), (1, 128, 32, 16), (3, 100, 24, 40), (2, 256, 64, 64)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _mk(shape, dtype, seed=0):
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("B,T,d,p", SHAPES_MM)
@pytest.mark.parametrize("dtype", DTYPES)
def test_ghost_norm_kernel(B, T, d, p, dtype):
    a, ds = _mk((B, T, d), dtype), _mk((B, T, p), dtype, 1)
    want = ref.ghost_norm_ref(a, ds)
    got = ops.ghost_norm_mm(a, ds, block_t=32)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("B,T,d,p", SHAPES_MM)
@pytest.mark.parametrize("dtype", DTYPES)
def test_direct_norm_kernel(B, T, d, p, dtype):
    a, ds = _mk((B, T, d), dtype), _mk((B, T, p), dtype, 1)
    want = ref.grad_norm_direct_ref(a, ds)
    got = ops.direct_norm_mm(a, ds, block_d=16, block_p=16)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_ghost_equals_direct_kernels():
    a, ds = _mk((2, 64, 24, 1)[:3] + (24,), jnp.float32), _mk((2, 64, 40), jnp.float32, 3)
    a = _mk((2, 64, 24), jnp.float32)
    np.testing.assert_allclose(ops.ghost_norm_mm(a, ds, block_t=16),
                               ops.direct_norm_mm(a, ds, block_d=8, block_p=8),
                               rtol=1e-4)


@pytest.mark.parametrize("B,T,d,p", [(2, 16, 8, 12), (3, 64, 40, 24)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_clipped_grad_kernel(B, T, d, p, dtype):
    a, ds = _mk((B, T, d), dtype), _mk((B, T, p), dtype, 1)
    C = jnp.abs(_mk((B,), jnp.float32, 2)) + 0.1
    want = ref.clipped_grad_ref(a, C, ds)
    got = ops.clipped_grad_mm(a, C, ds, block_d=16, block_p=16)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_stacked_layouts():
    a, ds = _mk((3, 2, 32, 8), jnp.float32), _mk((3, 2, 32, 12), jnp.float32, 1)
    from repro.core import ghost
    np.testing.assert_allclose(ops.ghost_norm_mm(a, ds, block_t=16),
                               ghost.sq_norm_mm_ghost(a, ds), rtol=1e-4)
    C = jnp.asarray([0.5, 2.0])
    np.testing.assert_allclose(
        ops.clipped_grad_mm(a, C, ds, block_d=8, block_p=8),
        ghost.weighted_grad_mm(a, C, ds, jnp.float32), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("B,T,S,H,K,h", [(1, 64, 64, 4, 2, 16),
                                         (2, 128, 128, 4, 4, 32)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", DTYPES)
def test_flash_attention_kernel(B, T, S, H, K, h, causal, dtype):
    q = _mk((B, T, H, h), dtype)
    k = _mk((B, S, K, h), dtype, 1)
    v = _mk((B, S, K, h), dtype, 2)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("B,T,H,h", [(1, 16, 2, 8), (2, 50, 3, 16), (1, 64, 2, 64)])
def test_wkv6_kernel(B, T, H, h):
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(keys[0], (B, T, H, h))
    k = jax.random.normal(keys[1], (B, T, H, h))
    v = jax.random.normal(keys[2], (B, T, H, h))
    w = jax.random.uniform(keys[3], (B, T, H, h), minval=0.5, maxval=0.999)
    u = jax.random.normal(keys[4], (H, h)) * 0.5
    want = ref.wkv6_ref(r, k, v, w, u)
    got = ops.wkv6(r, k, v, w, u, chunk=16)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_banded_attention_matches_masked_full():
    from repro.models.attention import banded_attention, multihead_attention
    B, T, H, K, h, W = 2, 128, 4, 2, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, T, H, h))
    k = jax.random.normal(ks[1], (B, T, K, h))
    v = jax.random.normal(ks[2], (B, T, K, h))
    want = multihead_attention(q, k, v, causal=True, window=W)
    got = banded_attention(q, k, v, window=W, chunk=32)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=2e-5, atol=2e-5)
