"""Privacy accounting: RDP of the Sampled Gaussian Mechanism."""
import math

import numpy as np
import pytest

from repro.core.accounting import (_log_a_frac, _log_a_int, budget_for,
                                   calibrate_sigma, compute_epsilon, rdp_sgm)


@pytest.mark.parametrize("q,sigma,alpha", [(0.01, 1.0, 4), (0.1, 2.0, 8),
                                           (0.004, 0.8, 16), (0.5, 1.5, 3)])
def test_int_alpha_matches_quadrature(q, sigma, alpha):
    """The integer-alpha binomial formula vs direct numerical integration."""
    np.testing.assert_allclose(_log_a_int(q, sigma, alpha),
                               _log_a_frac(q, sigma, float(alpha)),
                               rtol=1e-6, atol=1e-8)


def test_q1_matches_gaussian_closed_form():
    # non-subsampled Gaussian: RDP(alpha) = alpha / (2 sigma^2)
    for alpha in [2.0, 4.0, 16.0]:
        np.testing.assert_allclose(rdp_sgm(1.0, 2.0, alpha),
                                   alpha / (2 * 4.0), rtol=1e-9)


def test_subsampling_amplifies_privacy():
    assert rdp_sgm(0.01, 1.0, 8) < rdp_sgm(0.1, 1.0, 8) < rdp_sgm(1.0, 1.0, 8)


def test_epsilon_monotonicity():
    e1 = compute_epsilon(1.0, 0.01, 1000, 1e-5)
    assert e1 < compute_epsilon(1.0, 0.01, 4000, 1e-5)  # more steps
    assert e1 > compute_epsilon(2.0, 0.01, 1000, 1e-5)  # more noise
    assert e1 < compute_epsilon(1.0, 0.04, 1000, 1e-5)  # bigger q


def test_calibration_roundtrip():
    sigma = calibrate_sigma(3.0, 0.01, 2000, 1e-5)
    eps = compute_epsilon(sigma, 0.01, 2000, 1e-5)
    assert eps <= 3.0 + 1e-6
    assert eps > 2.5  # not absurdly conservative


def test_budget_for_gpt2_e2e_setting():
    """Paper-style setting: E2E dataset ~42k samples, eps=3."""
    b = budget_for(3.0, 1e-5, batch_size=1024, dataset_size=42000, epochs=10)
    assert b.epsilon <= 3.0
    assert 0.3 < b.sigma < 5.0
    assert b.steps == math.ceil(10 * 42000 / 1024)


# ------------------------------------------------- tree-aggregation accountant
def test_tree_node_count():
    from repro.core.accounting import tree_node_count
    # one tree over 2^k leaves: root path touches k+1 nodes
    assert tree_node_count(8) == 4
    assert tree_node_count(5) == 4            # padded to next_pow2(5) = 8
    assert tree_node_count(1) == 1
    # restarts shrink the per-tree height; participations is TOTAL
    # appearances, never multiplied by the epoch count again
    assert tree_node_count(100, restart_every=16) == 5
    assert tree_node_count(100, restart_every=16, participations=7) == 35
    # multiple passes through ONE tree multiply the touched nodes
    assert tree_node_count(8, participations=3) == 12
    assert tree_node_count(0) == 0


def test_tree_epsilon_monotone_and_restart_height():
    from repro.core.accounting import compute_epsilon_tree
    e = compute_epsilon_tree(2.0, 256, 1e-5)
    assert e > compute_epsilon_tree(4.0, 256, 1e-5)       # more noise
    assert e < compute_epsilon_tree(2.0, 4096, 1e-5)      # longer run
    # at EQUAL participations restarts only shrink the per-tree height
    assert compute_epsilon_tree(2.0, 256, 1e-5, restart_every=16) < e
    # ... the multi-epoch cost enters through participations (data passes)
    assert compute_epsilon_tree(2.0, 256, 1e-5, restart_every=16,
                                participations=16) > e
    assert compute_epsilon_tree(0.0, 256, 1e-5) == float("inf")


def test_tree_matches_gaussian_closed_form_at_m1():
    """steps=1 is a single released node: plain Gaussian mechanism."""
    from repro.core.accounting import compute_epsilon, compute_epsilon_tree
    # q=1 SGM over 1 step == Gaussian == tree with m=1
    np.testing.assert_allclose(compute_epsilon_tree(2.0, 1, 1e-5),
                               compute_epsilon(2.0, 1.0, 1, 1e-5), rtol=1e-9)


def test_tree_calibration_roundtrip_and_no_amplification():
    from repro.core.accounting import calibrate_sigma_tree, compute_epsilon_tree
    sigma = calibrate_sigma_tree(3.0, 512, 1e-5, restart_every=128)
    eps = compute_epsilon_tree(sigma, 512, 1e-5, restart_every=128)
    assert eps <= 3.0 + 1e-6 and eps > 2.5
    # DP-FTRL gets no subsampling amplification: its sigma for the same
    # (eps, steps) budget must exceed the q<<1 SGM sigma
    b_tree = budget_for(3.0, 1e-5, 64, 50000, 1.0, mechanism="tree")
    b_sgm = budget_for(3.0, 1e-5, 64, 50000, 1.0)
    assert b_tree.sigma > b_sgm.sigma
    assert b_tree.mechanism == "tree" and b_sgm.mechanism == "sgm"


def test_budget_for_rejects_unknown_mechanism():
    with pytest.raises(ValueError):
        budget_for(3.0, 1e-5, 64, 50000, 1.0, mechanism="nope")


# ------------------------------------------------------------------- ledger
def test_ledger_matches_direct_accountants():
    from repro.core.accounting import (PrivacyLedger, compute_epsilon_tree)
    led = PrivacyLedger()
    led.record_to(500, sigma=1.0, sample_rate=0.01)
    np.testing.assert_allclose(led.epsilon(1e-5),
                               compute_epsilon(1.0, 0.01, 500, 1e-5),
                               rtol=1e-9)
    led = PrivacyLedger()
    led.record_to(64, sigma=2.0, sample_rate=1.0, mechanism="tree",
                  restart_every=16)
    np.testing.assert_allclose(led.epsilon(1e-5),
                               compute_epsilon_tree(2.0, 64, 1e-5,
                                                    restart_every=16),
                               rtol=1e-9)


def test_ledger_replay_is_idempotent():
    """Re-recording already-covered absolute steps (a restart replaying the
    lost tail) must not double-count budget."""
    from repro.core.accounting import PrivacyLedger
    led = PrivacyLedger()
    led.record_to(100, sigma=1.0, sample_rate=0.01)
    eps = led.epsilon(1e-5)
    assert led.record_to(80, sigma=1.0, sample_rate=0.01) == 0  # replay
    assert led.record_to(100, sigma=1.0, sample_rate=0.01) == 0
    assert led.epsilon(1e-5) == eps
    assert led.record_to(120, sigma=1.0, sample_rate=0.01) == 20
    assert led.epsilon(1e-5) > eps


def test_ledger_tree_segments_merge_as_one_release():
    """A tree release split across restarts must account like the unsplit
    run (same continued tree), not like two composed releases."""
    from repro.core.accounting import PrivacyLedger
    whole = PrivacyLedger()
    whole.record_to(64, sigma=2.0, sample_rate=1.0, mechanism="tree",
                    restart_every=16)
    split = PrivacyLedger()
    split.record_to(40, sigma=2.0, sample_rate=1.0, mechanism="tree",
                    restart_every=16)
    split.record_to(64, sigma=2.0, sample_rate=1.0, mechanism="tree",
                    restart_every=16)
    np.testing.assert_allclose(split.epsilon(1e-5), whole.epsilon(1e-5),
                               rtol=1e-12)
    # a sigma change is a NEW release: composes additively, costs more
    hetero = PrivacyLedger()
    hetero.record_to(40, sigma=2.0, sample_rate=1.0, mechanism="tree",
                     restart_every=16)
    hetero.record_to(64, sigma=1.0, sample_rate=1.0, mechanism="tree",
                     restart_every=16)
    assert hetero.epsilon(1e-5) > whole.epsilon(1e-5)
    assert len(hetero.entries) == 2


def test_ledger_json_roundtrip_and_version_gate():
    from repro.core.accounting import PrivacyLedger
    led = PrivacyLedger()
    led.record_to(10, sigma=1.0, sample_rate=0.1)
    led.record_to(30, sigma=0.5, sample_rate=0.1)
    back = PrivacyLedger.from_json(led.to_json())
    assert back.recorded_to == 30 and back.entries == led.entries
    np.testing.assert_allclose(back.epsilon(1e-5), led.epsilon(1e-5))
    assert PrivacyLedger.from_json(None).recorded_to == 0
    with pytest.raises(ValueError, match="version"):
        PrivacyLedger.from_json({"version": 99})
    with pytest.raises(ValueError, match="cover"):
        PrivacyLedger(entries=[{"steps": 5, "sigma": 1.0,
                                "sample_rate": 0.1}], recorded_to=9)


def test_ledger_zero_sigma_is_infinite():
    from repro.core.accounting import PrivacyLedger
    led = PrivacyLedger()
    led.record_to(5, sigma=0.0, sample_rate=0.1)
    assert led.epsilon(1e-5) == float("inf")
