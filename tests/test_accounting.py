"""Privacy accounting: RDP of the Sampled Gaussian Mechanism."""
import math

import numpy as np
import pytest

from repro.core.accounting import (_log_a_frac, _log_a_int, budget_for,
                                   calibrate_sigma, compute_epsilon, rdp_sgm)


@pytest.mark.parametrize("q,sigma,alpha", [(0.01, 1.0, 4), (0.1, 2.0, 8),
                                           (0.004, 0.8, 16), (0.5, 1.5, 3)])
def test_int_alpha_matches_quadrature(q, sigma, alpha):
    """The integer-alpha binomial formula vs direct numerical integration."""
    np.testing.assert_allclose(_log_a_int(q, sigma, alpha),
                               _log_a_frac(q, sigma, float(alpha)),
                               rtol=1e-6, atol=1e-8)


def test_q1_matches_gaussian_closed_form():
    # non-subsampled Gaussian: RDP(alpha) = alpha / (2 sigma^2)
    for alpha in [2.0, 4.0, 16.0]:
        np.testing.assert_allclose(rdp_sgm(1.0, 2.0, alpha),
                                   alpha / (2 * 4.0), rtol=1e-9)


def test_subsampling_amplifies_privacy():
    assert rdp_sgm(0.01, 1.0, 8) < rdp_sgm(0.1, 1.0, 8) < rdp_sgm(1.0, 1.0, 8)


def test_epsilon_monotonicity():
    e1 = compute_epsilon(1.0, 0.01, 1000, 1e-5)
    assert e1 < compute_epsilon(1.0, 0.01, 4000, 1e-5)  # more steps
    assert e1 > compute_epsilon(2.0, 0.01, 1000, 1e-5)  # more noise
    assert e1 < compute_epsilon(1.0, 0.04, 1000, 1e-5)  # bigger q


def test_calibration_roundtrip():
    sigma = calibrate_sigma(3.0, 0.01, 2000, 1e-5)
    eps = compute_epsilon(sigma, 0.01, 2000, 1e-5)
    assert eps <= 3.0 + 1e-6
    assert eps > 2.5  # not absurdly conservative


def test_budget_for_gpt2_e2e_setting():
    """Paper-style setting: E2E dataset ~42k samples, eps=3."""
    b = budget_for(3.0, 1e-5, batch_size=1024, dataset_size=42000, epochs=10)
    assert b.epsilon <= 3.0
    assert 0.3 < b.sigma < 5.0
    assert b.steps == math.ceil(10 * 42000 / 1024)
