"""Elastic-restart acceptance tests: fault-injected training subprocesses
(SIGKILL at a step, SIGKILL mid-checkpoint-write, SIGTERM preemption) must
resume to BITWISE-identical final params and IDENTICAL reported epsilon vs
an uninterrupted reference — for both the SGD/gaussian and FTRL/tree-noise
paths — and process-sliced checkpoints must restore onto a different
device count.

Each training run is the real CLI driver (``repro.launch.train.main``) in a
subprocess, with faults injected through the ``REPRO_FAULT`` env channel
(runtime.fault_injection) — the exact production command line, crashed and
restarted the way a scheduler would."""
import json
import os

import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.runtime import fault_injection as fi

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {"PYTHONPATH": "src"}
STEPS = 8


def _train_code(ckpt_dir: str, out: str, steps: int = STEPS,
                optimizer: str = "sgd", extra=()) -> str:
    argv = ["--arch", "qwen2-1.5b", "--smoke", "--steps", str(steps),
            "--batch", "4", "--seq", "16", "--lr", "1e-3",
            "--optimizer", optimizer, "--mode", "bk", "--policy", "",
            "--sigma", "0.5", "--log-every", "100",
            "--ckpt-dir", ckpt_dir, "--ckpt-every", "2", "--out", out]
    if optimizer == "ftrl":
        argv += ["--restart-every", "4"]
    argv += list(extra)
    return (f"import sys\nsys.argv = ['train'] + {argv!r}\n"
            "from repro.launch.train import main\nmain()\n")


def _run_train(ckpt_dir, out, fault=None, env=ENV, **kw):
    r = fi.run_subprocess(_train_code(str(ckpt_dir), str(out), **kw),
                          fault=fault, env=env, cwd=ROOT)
    return r


def _summary(out) -> dict:
    with open(out) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def reference(tmp_path_factory):
    """Uninterrupted runs (one per optimizer path): the ground truth the
    crashed-and-resumed runs must reproduce bitwise."""
    refs = {}
    for opt in ("sgd", "ftrl"):
        d = tmp_path_factory.mktemp(f"ref_{opt}")
        _run_train(d / "ck", d / "out.json", optimizer=opt)
        refs[opt] = _summary(d / "out.json")
        assert refs[opt]["steps_done"] == STEPS
        assert refs[opt]["resumed_from"] == 0
        assert np.isfinite(refs[opt]["epsilon"])
    return refs


@pytest.mark.parametrize("opt,kill_step", [("sgd", 5), ("ftrl", 6)])
def test_sigkill_resume_bitwise(tmp_path, reference, opt, kill_step):
    """SIGKILL mid-run, resume, finish: final params bitwise-identical and
    epsilon identical to the run that never crashed. The FTRL case crosses
    a tree/anchor restart boundary (restart_every=4) before dying."""
    ck, out = tmp_path / "ck", tmp_path / "out.json"
    _run_train(ck, out, optimizer=opt,
               fault=fi.FaultSpec("step", kill_step, "sigkill"))
    assert not os.path.exists(out)              # died before the summary
    assert ckpt.latest_step(str(ck)) is not None
    _run_train(ck, out, optimizer=opt)          # restart: same command line
    got = _summary(out)
    assert got["resumed_from"] > 0              # really resumed, not re-ran
    assert got["steps_done"] == STEPS
    assert got["params_sha256"] == reference[opt]["params_sha256"]
    assert got["epsilon"] == reference[opt]["epsilon"]
    assert got["ledger"]["recorded_to"] == \
        reference[opt]["ledger"]["recorded_to"]


def test_sigterm_preemption_graceful_resume(tmp_path, reference):
    """SIGTERM (scheduler preemption) takes the graceful path: the guard
    flips, the loop force-checkpoints the current step and exits 0; the
    restarted run continues to the same bitwise result."""
    ck, out = tmp_path / "ck", tmp_path / "out.json"
    r = _run_train(ck, out, fault=fi.FaultSpec("step", 3, "sigterm"))
    assert "preempted at step 3" in r.stdout
    assert ckpt.latest_step(str(ck)) == 3       # the forced preemption save
    _run_train(ck, out)
    got = _summary(out)
    assert got["resumed_from"] == 4
    assert got["params_sha256"] == reference["sgd"]["params_sha256"]
    assert got["epsilon"] == reference["sgd"]["epsilon"]


def test_sigkill_mid_checkpoint_write_resume(tmp_path, reference):
    """SIGKILL while the checkpoint payload is being written (manifest not
    yet on disk): the torn write must be invisible — only a .tmp dir left,
    never a listed step — and the rerun still converges to the reference."""
    ck, out = tmp_path / "ck", tmp_path / "out.json"
    _run_train(ck, out, fault=fi.FaultSpec("ckpt_mid_write",
                                           action="sigkill"))
    assert ckpt.steps(str(ck)) == []            # nothing committed
    assert ckpt.latest_step(str(ck)) is None
    leftovers = os.listdir(str(ck))
    assert leftovers and all(d.endswith(".tmp") for d in leftovers)
    _run_train(ck, out)                         # restarts from scratch
    got = _summary(out)
    assert got["params_sha256"] == reference["sgd"]["params_sha256"]
    assert got["epsilon"] == reference["sgd"]["epsilon"]


def test_sigkill_pre_commit_leaves_no_checkpoint(tmp_path):
    """SIGKILL after payload + manifest are fully written but before the
    atomic rename: still no visible checkpoint, and a later save at the
    same step clears the stale staging dir and commits cleanly."""
    ck, out = tmp_path / "ck", tmp_path / "out.json"
    _run_train(ck, out, fault=fi.FaultSpec("ckpt_pre_commit",
                                           action="sigkill"))
    assert ckpt.latest_step(str(ck)) is None
    tmp_dirs = [d for d in os.listdir(str(ck)) if d.endswith(".tmp")]
    assert tmp_dirs, "pre-commit kill should leave the staging dir"
    assert os.path.exists(os.path.join(str(ck), tmp_dirs[0],
                                       ckpt.MANIFEST))
    # a fresh save at the same step reuses the path cleanly
    ckpt.save(str(ck), 0, {"w": np.ones((2, 2), np.float32)})
    assert ckpt.latest_step(str(ck)) == 0


# ------------------------------------------------- elastic device-count moves
def test_sliced_checkpoint_restores_on_different_device_count(tmp_path):
    """Train on a 4-device (2 data x 2 model) mesh — the checkpoint is
    written as per-shard slices — then restore on ONE device: the assembled
    global params are bitwise-identical to the saving run's, and a resumed
    training run on the new topology continues the ledger."""
    ck, out_a = tmp_path / "ck", tmp_path / "outA.json"
    env4 = dict(ENV, XLA_FLAGS="--xla_force_host_platform_device_count=4")
    fi.run_subprocess(
        _train_code(str(ck), str(out_a), steps=4,
                    extra=["--mesh", "2,2", "--ckpt-every", "1"]),
        env=env4, cwd=ROOT)
    ref = _summary(out_a)
    assert ckpt.latest_step(str(ck)) == 3

    # the payload really is sliced: some slice starts at a nonzero offset
    with open(os.path.join(str(ck), "step_0000000003",
                           ckpt.MANIFEST)) as f:
        manifest = json.load(f)
    entries = [e for finfo in manifest["files"].values()
               for e in finfo["entries"].values()]
    assert any(any(o > 0 for o in e["offset"]) for e in entries), \
        "expected model-sharded leaves to produce offset slices"

    # single-device restore assembles the global arrays bitwise
    code = (
        "from repro.checkpoint import checkpoint as ckpt\n"
        "from repro.checkpoint.run_state import params_digest\n"
        f"state, step, meta = ckpt.restore({str(ck)!r})\n"
        "print('STEP', step)\n"
        "print('DIGEST', params_digest(state['params']))\n")
    r = fi.run_subprocess(code, env=ENV, cwd=ROOT)
    assert "STEP 3" in r.stdout
    assert f"DIGEST {ref['params_sha256']}" in r.stdout

    # and a 1-device run resumes training + the ledger from the 4-device
    # checkpoint (different mesh shape, same privacy history)
    out_b = tmp_path / "outB.json"
    fi.run_subprocess(_train_code(str(ck), str(out_b), steps=6), env=ENV,
                      cwd=ROOT)
    got = _summary(out_b)
    assert got["resumed_from"] == 4
    assert got["steps_done"] == 6
    assert np.isfinite(got["epsilon"]) and got["epsilon"] > ref["epsilon"]


# ----------------------------------------- sliced-format unit tests (no jax)
def _two_host_slices():
    a = np.arange(24, dtype=np.float32).reshape(4, 6)
    top = ckpt.ShardSlice("params/w", (0, 0), (2, 6), (4, 6), "float32",
                          a[:2])
    bot = ckpt.ShardSlice("params/w", (2, 0), (2, 6), (4, 6), "float32",
                          a[2:])
    step = ckpt.ShardSlice("step", (), (), (), "int64",
                           np.asarray(3, np.int64))
    return a, top, bot, step


def test_multi_process_sliced_save_roundtrip(tmp_path):
    """Two hosts write disjoint slice files; commit unions them; restore
    reassembles the global array exactly."""
    a, top, bot, step = _two_host_slices()
    tmp = ckpt.stage_dir(str(tmp_path), 3)
    f0, i0, m0 = ckpt.write_shard_file(tmp, 0, [top, step])
    f1, i1, m1 = ckpt.write_shard_file(tmp, 1, [bot])
    ckpt.commit(str(tmp_path), 3, tmp, {f0: i0, f1: i1}, {**m0, **m1},
                meta={"k": 1}, process_count=2)
    state, got_step, meta = ckpt.restore(str(tmp_path))
    assert got_step == 3 and meta == {"k": 1}
    np.testing.assert_array_equal(state["params"]["w"], a)
    assert int(state["step"]) == 3


def test_restore_rejects_incomplete_coverage(tmp_path):
    """A manifest whose slices don't cover an array (lost host file) must
    raise, never silently restore zeros."""
    a, top, bot, step = _two_host_slices()
    tmp = ckpt.stage_dir(str(tmp_path), 1)
    f0, i0, m0 = ckpt.write_shard_file(tmp, 0, [top, step])
    _, _, m1 = ckpt.write_shard_file(tmp, 1, [bot])
    # commit lists only host 0's file but the union's global shapes
    ckpt.commit(str(tmp_path), 1, tmp, {f0: i0}, {**m0, **m1})
    with pytest.raises(IOError, match="coverage"):
        ckpt.restore(str(tmp_path), step=1)


def test_restore_rejects_crc_mismatch(tmp_path):
    a, top, bot, step = _two_host_slices()
    ckpt.save(str(tmp_path), 2, [top, bot, step])
    mpath = os.path.join(str(tmp_path), "step_0000000002", ckpt.MANIFEST)
    with open(mpath) as f:
        manifest = json.load(f)
    fname = next(iter(manifest["files"]))
    key = next(iter(manifest["files"][fname]["entries"]))
    manifest["files"][fname]["entries"][key]["crc"] ^= 0xFF
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(IOError, match="checksum"):
        ckpt.restore(str(tmp_path), step=2)


def test_restore_rejects_replica_disagreement(tmp_path):
    """Two hosts claiming the same offset with different bytes is a
    corrupted replicated leaf — restore must refuse to pick one."""
    a, top, bot, step = _two_host_slices()
    top2 = ckpt.ShardSlice("params/w", (0, 0), (2, 6), (4, 6), "float32",
                           a[:2] + 1.0)
    tmp = ckpt.stage_dir(str(tmp_path), 4)
    f0, i0, m0 = ckpt.write_shard_file(tmp, 0, [top, bot, step])
    f1, i1, m1 = ckpt.write_shard_file(tmp, 1, [top2])
    ckpt.commit(str(tmp_path), 4, tmp, {f0: i0, f1: i1}, {**m0, **m1},
                process_count=2)
    with pytest.raises(IOError, match="disagreement"):
        ckpt.restore(str(tmp_path), step=4)


def test_template_subset_and_missing_key(tmp_path):
    """Template keys must exist in the checkpoint (missing -> error); extra
    checkpoint keys pass through untouched."""
    ckpt.save(str(tmp_path), 5, {"a": np.ones(3, np.float32),
                                 "extra": np.zeros(2, np.float32)})
    state, _, _ = ckpt.restore(str(tmp_path),
                               template={"a": np.zeros(3, np.float64)})
    assert state["a"].dtype == np.float64       # cast to template dtype
    assert "extra" in state                     # passes through
    with pytest.raises(IOError, match="lacks template keys"):
        ckpt.restore(str(tmp_path), template={"missing": np.zeros(1)})
