"""Property-based tests (hypothesis) for the ghost-norm identities — the
system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import ghost

jax.config.update("jax_enable_x64", False)


def arrays(shape, seed):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(*shape).astype(np.float32))


@settings(max_examples=25, deadline=None)
@given(B=st.integers(1, 4), T=st.integers(1, 6), d=st.integers(1, 8),
       p=st.integers(1, 8), seed=st.integers(0, 2**16))
def test_ghost_equals_direct_mm(B, T, d, p, seed):
    a = arrays((B, T, d), seed)
    ds = arrays((B, T, p), seed + 1)
    g = np.einsum("btd,btp->bdp", a, ds)
    want = np.sum(g * g, axis=(1, 2))
    np.testing.assert_allclose(ghost.sq_norm_mm_ghost(a, ds), want, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(ghost.sq_norm_mm_direct(a, ds), want, rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(L=st.integers(1, 3), B=st.integers(1, 3), T=st.integers(1, 5),
       d=st.integers(1, 6), p=st.integers(1, 6), seed=st.integers(0, 2**16))
def test_ghost_stacked_sums_over_layers(L, B, T, d, p, seed):
    a = arrays((L, B, T, d), seed)
    ds = arrays((L, B, T, p), seed + 1)
    g = np.einsum("lbtd,lbtp->lbdp", a, ds)
    want = np.sum(g * g, axis=(0, 2, 3))
    np.testing.assert_allclose(ghost.sq_norm_mm_ghost(a, ds), want, rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(B=st.integers(1, 4), T=st.integers(1, 6), V=st.integers(2, 10),
       d=st.integers(1, 6), seed=st.integers(0, 2**16))
def test_embedding_ghost_norm(B, T, V, d, seed):
    rng = np.random.RandomState(seed)
    ids = jnp.asarray(rng.randint(0, V, (B, T)))
    ds = arrays((B, T, d), seed + 1)
    # oracle: scatter into one-hot per-sample grads
    onehot = np.eye(V)[np.asarray(ids)]  # (B,T,V)
    g = np.einsum("btv,btd->bvd", onehot, ds)
    want = np.sum(g * g, axis=(1, 2))
    np.testing.assert_allclose(ghost.sq_norm_emb(ids, ds), want, rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(B=st.integers(1, 3), T=st.integers(1, 5), d=st.integers(1, 6),
       p=st.integers(1, 6), seed=st.integers(0, 2**16))
def test_weighted_grad_mm(B, T, d, p, seed):
    a = arrays((B, T, d), seed)
    ds = arrays((B, T, p), seed + 1)
    C = jnp.abs(arrays((B,), seed + 2)) + 0.1
    want = np.einsum("btd,b,btp->dp", a, C, ds)
    np.testing.assert_allclose(ghost.weighted_grad_mm(a, C, ds), want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(B=st.integers(1, 3), E=st.integers(1, 4), C=st.integers(1, 5),
       d=st.integers(1, 5), p=st.integers(1, 5), seed=st.integers(0, 2**16))
def test_moe_ghost_vs_direct(B, E, C, d, p, seed):
    rng = np.random.RandomState(seed)
    a = arrays((B, E, C, d), seed)
    mask = jnp.asarray((rng.rand(B, E, C) > 0.3).astype(np.float32))
    ds = arrays((B, E, C, p), seed + 1)
    rec = {"a": a, "mask": mask}
    am = np.asarray(a) * np.asarray(mask)[..., None]
    dm = np.asarray(ds) * np.asarray(mask)[..., None]
    g = np.einsum("becd,becp->bedp", am, dm)
    want = np.sum(g * g, axis=(1, 2, 3))
    np.testing.assert_allclose(ghost.sq_norm_moe_ghost(rec, ds), want, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(ghost.sq_norm_moe_direct(rec, ds), want, rtol=1e-4, atol=1e-5)


def test_hybrid_rule_matches_paper_examples():
    # Paper Sec 3.1: ImageNet conv1 of VGG11: 2T^2 = 5e9 >> pd = 1.7e3 -> direct
    assert not ghost.prefer_ghost(T=224 * 224, d=27, p=64)
    # RoBERTa: T=256, layer ~1-4M params -> ghost
    assert ghost.prefer_ghost(T=256, d=1024, p=1024)
