"""Core correctness: every DP implementation (BK, hybrids, baselines) computes
the SAME private gradient — the paper's central claim that BK changes the
cost, not the optimizer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bk import DPConfig
from repro.core.engine import ALL_MODES, make_grad_fn
from repro.models.mlp import MLP, MLPConfig
from repro.utils.tree import flatten

B = 8


def _setup(bias=True, clipping="automatic", sigma=0.0):
    model = MLP(MLPConfig(d_in=12, width=16, depth=3, n_classes=5, bias=bias))
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = {
        "x": jax.random.normal(jax.random.PRNGKey(1), (B, 12)),
        "y": jax.random.randint(jax.random.PRNGKey(2), (B,), 0, 5),
    }
    return model, params, batch


def _grads(model, params, batch, mode, clipping="automatic", sigma=0.0):
    cfg = DPConfig(mode=mode, clipping=clipping, R=1.0, sigma=sigma)
    fn = jax.jit(make_grad_fn(model.apply, cfg))
    return fn(params, batch, jax.random.PRNGKey(7))


DP_MODES = [m for m in ALL_MODES if m != "nonprivate"]


@pytest.mark.parametrize("mode", DP_MODES)
@pytest.mark.parametrize("clipping", ["automatic", "abadi", "flat"])
def test_all_modes_agree_with_opacus(mode, clipping):
    model, params, batch = _setup()
    ref, ref_aux = _grads(model, params, batch, "opacus", clipping)
    got, aux = _grads(model, params, batch, mode, clipping)
    np.testing.assert_allclose(aux["per_sample_norms"], ref_aux["per_sample_norms"],
                               rtol=1e-5, atol=1e-6)
    for (p, g), (_, r) in zip(sorted(flatten(got).items()), sorted(flatten(ref).items())):
        np.testing.assert_allclose(g, r, rtol=1e-4, atol=1e-6, err_msg=p)


@pytest.mark.parametrize("mode", DP_MODES)
def test_noise_identical_across_modes(mode):
    """Same rng -> identical noise regardless of implementation."""
    model, params, batch = _setup()
    ref, _ = _grads(model, params, batch, "opacus", sigma=0.7)
    got, _ = _grads(model, params, batch, mode, sigma=0.7)
    for (p, g), (_, r) in zip(sorted(flatten(got).items()), sorted(flatten(ref).items())):
        np.testing.assert_allclose(g, r, rtol=1e-4, atol=1e-5, err_msg=p)


def test_grads_tree_matches_params_tree():
    model, params, batch = _setup()
    grads, _ = _grads(model, params, batch, "bk")
    assert jax.tree_util.tree_structure(grads) == jax.tree_util.tree_structure(params)
    for p, g in flatten(grads).items():
        assert g.shape == flatten(params)[p].shape, p


def test_clip_factors_bound_sensitivity():
    model, params, batch = _setup(clipping="abadi")
    _, aux = _grads(model, params, batch, "bk", clipping="abadi")
    clipped = aux["per_sample_norms"] * aux["clip_factors"]
    assert np.all(np.asarray(clipped) <= 1.0 + 1e-5)


def test_nonprivate_matches_plain_grad():
    model, params, batch = _setup()
    cfg = DPConfig(mode="nonprivate")
    grads, aux = make_grad_fn(model.apply, cfg)(params, batch, jax.random.PRNGKey(0))
    from repro.core.tape import Tape

    def mean_loss(p):
        return jnp.mean(model.apply(p, batch, Tape(None)))

    ref = jax.grad(mean_loss)(params)
    for (p, g), (_, r) in zip(sorted(flatten(grads).items()), sorted(flatten(ref).items())):
        np.testing.assert_allclose(g, r, rtol=1e-5, atol=1e-7, err_msg=p)


def test_bk_no_bias_model():
    model, params, batch = _setup(bias=False)
    ref, _ = _grads(model, params, batch, "opacus")
    got, _ = _grads(model, params, batch, "bk")
    for (p, g), (_, r) in zip(sorted(flatten(got).items()), sorted(flatten(ref).items())):
        np.testing.assert_allclose(g, r, rtol=1e-4, atol=1e-6, err_msg=p)


def test_bk_with_fused_kernels_matches_reference():
    """DPConfig(use_kernels=True) routes norms/weighted-grads through the
    Pallas kernels (interpret mode on CPU) — must equal the einsum path."""
    model, params, batch = _setup()
    ref, ra = _grads(model, params, batch, "bk")
    cfg = DPConfig(mode="bk", clipping="automatic", R=1.0, use_kernels=True)
    from repro.core.engine import make_grad_fn as mk
    got, ga = mk(model.apply, cfg)(params, batch, jax.random.PRNGKey(7))
    np.testing.assert_allclose(ga["per_sample_norms"], ra["per_sample_norms"],
                               rtol=1e-4, atol=1e-6)
    for (p, g), (_, r) in zip(sorted(flatten(got).items()),
                              sorted(flatten(ref).items())):
        np.testing.assert_allclose(g, r, rtol=1e-4, atol=1e-6, err_msg=p)


def test_bk_mixopt_with_fused_kernels():
    model, params, batch = _setup()
    ref, ra = _grads(model, params, batch, "opacus")
    cfg = DPConfig(mode="bk-mixopt", clipping="abadi", R=1.0, use_kernels=True)
    from repro.core.engine import make_grad_fn as mk
    got, ga = mk(model.apply, cfg)(params, batch, jax.random.PRNGKey(7))
    np.testing.assert_allclose(ga["per_sample_norms"], ra["per_sample_norms"],
                               rtol=1e-4, atol=1e-6)
