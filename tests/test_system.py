"""End-to-end behaviour tests: full train driver with checkpoint/restart
determinism, serve driver, engine facade, HLO analyzer, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import build, smoke_config
from repro.core.bk import DPConfig
from repro.launch.train import train


def _smoke_cfg():
    return smoke_config("qwen2-1.5b").with_(dtype="float32",
                                            param_dtype="float32")


def test_train_loop_end_to_end(tmp_path):
    """Loss decreases under DP training; checkpoints are written."""
    tc = TrainConfig(global_batch=8, microbatch=4, seq_len=16, steps=12,
                     lr=2e-3, checkpoint_dir=str(tmp_path),
                     checkpoint_every=5)
    dp = DPConfig(mode="bk-mixopt", clipping="automatic", sigma=0.3)
    params, losses = train(_smoke_cfg(), tc, dp, log=lambda *a: None)
    assert len(losses) == 12
    assert np.mean(losses[-3:]) < np.mean(losses[:3])
    from repro.checkpoint import checkpoint as ckpt
    assert ckpt.latest_step(str(tmp_path)) is not None


def test_train_resume_exact(tmp_path):
    """train(12) == train(7) + resume(5) bit-exactly (fault tolerance)."""
    dp = DPConfig(mode="bk", clipping="automatic", sigma=0.2)
    tc_full = TrainConfig(global_batch=4, seq_len=16, steps=10, lr=1e-3,
                          lr_schedule="constant")
    p_full, _ = train(_smoke_cfg(), tc_full, dp, log=lambda *a: None)

    tc_a = TrainConfig(global_batch=4, seq_len=16, steps=6, lr=1e-3,
                       lr_schedule="constant",
                       checkpoint_dir=str(tmp_path), checkpoint_every=1)
    train(_smoke_cfg(), tc_a, dp, log=lambda *a: None)
    tc_b = TrainConfig(global_batch=4, seq_len=16, steps=10, lr=1e-3,
                       lr_schedule="constant",
                       checkpoint_dir=str(tmp_path), checkpoint_every=100)
    p_resumed, _ = train(_smoke_cfg(), tc_b, dp, log=lambda *a: None)

    from repro.utils.tree import flatten
    for k, v in flatten(p_full).items():
        np.testing.assert_allclose(np.asarray(v),
                                   np.asarray(flatten(p_resumed)[k]),
                                   rtol=1e-6, atol=1e-7, err_msg=k)


def test_generate_roundtrip():
    cfg = _smoke_cfg()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.launch.serve import generate
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab)
    out = generate(model, params, prompts, gen_len=4)
    assert out.shape == (2, 10)
    np.testing.assert_array_equal(np.asarray(out[:, :6]), np.asarray(prompts))


def test_hlo_analyzer_trip_counts():
    from repro.utils.hlo import analyze_hlo, xla_cost_analysis
    D, L = 64, 8

    def f(params, x0):
        def body(h, wl):
            return jnp.tanh(h @ wl), None
        h, _ = jax.lax.scan(body, x0, params)
        return h

    co = jax.jit(f).lower(jax.ShapeDtypeStruct((L, D, D), jnp.float32),
                          jax.ShapeDtypeStruct((D, D), jnp.float32)).compile()
    t = analyze_hlo(co.as_text())
    assert abs(t["flops"] - 2 * D**3 * L) / (2 * D**3 * L) < 1e-6
    # XLA's own analysis undercounts by the trip count
    assert xla_cost_analysis(co)["flops"] < t["flops"]


def test_xla_cost_analysis_normalizes_both_shapes():
    """cost_analysis() returns a dict on older jax, [dict] on newer — the
    helper must take both (and tolerate empties)."""
    from repro.utils.hlo import xla_cost_analysis

    class Dict:
        def cost_analysis(self):
            return {"flops": 7.0}

    class List:
        def cost_analysis(self):
            return [{"flops": 7.0}]

    class Empty:
        def cost_analysis(self):
            return []

    assert xla_cost_analysis(Dict()) == {"flops": 7.0}
    assert xla_cost_analysis(List()) == {"flops": 7.0}
    assert xla_cost_analysis(Empty()) == {}


def test_sharding_rules_sanitize():
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import sanitize, spec_for
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16, "pod": 2}
    assert sanitize(P("data", "model"), (32, 32001), FakeMesh()) == P("data", None)
    assert sanitize(P(("pod", "data"),), (1,), FakeMesh()) == P(None)
    assert sanitize(P(None, "model"), (77, 64), FakeMesh()) == P(None, "model")
    assert spec_for("blocks/attn/qkv/w", 3) == P(None, "data", "model")
    assert spec_for("blocks/ln1/g", 2) == P()
    assert spec_for("embed/w", 2) == P(None, "model")


def test_engine_rejects_unknown_mode():
    from repro.core.engine import make_grad_fn
    with pytest.raises(ValueError):
        make_grad_fn(lambda *a: None, DPConfig(mode="nope"))
