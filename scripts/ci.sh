#!/usr/bin/env bash
# CI entry point: tier-1 tests + fast benchmark validation + kernel bench.
#
#   bash scripts/ci.sh
#
# Runs everything even if an early stage fails (so one run collects every
# signal). Tier-1 gating is REGRESSION-based: we parse the pass/fail counts
# and fail the run if the failure count regresses past the baseline or the
# passed count drops below the floor. The seed snapshot shipped with 16
# known failures; PR 2 fixed 14 (dryrun mesh cells), PR 3 fixed the last 2
# (end-to-end loss plateau, hlo cost_analysis shape) — the suite is gated
# GREEN (0 failures) from PR 3 on.
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

BASELINE="${TIER1_BASELINE_FAILURES:-0}"
# floor excludes tests/test_sharded_step.py (6 tests): it gates in its own
# dedicated stage below
PASS_FLOOR="${TIER1_BASELINE_PASSED:-290}"
LOG="$(mktemp)"
trap 'rm -f "$LOG"' EXIT

echo "== tier-1: pytest (baseline: <=$BASELINE failed, >=$PASS_FLOOR passed) =="
# test_sharded_step runs in its own dedicated stage below — running its
# multi-minute 8-fake-device subprocesses twice per CI pass is pure waste
python -m pytest -q --ignore=tests/test_sharded_step.py 2>&1 | tee "$LOG"
failed="$(grep -oE '[0-9]+ failed' "$LOG" | tail -1 | grep -oE '[0-9]+' || echo 0)"
passed="$(grep -oE '[0-9]+ passed' "$LOG" | tail -1 | grep -oE '[0-9]+' || echo 0)"
errors="$(grep -oE '[0-9]+ errors?([, ]|$)' "$LOG" | tail -1 | grep -oE '[0-9]+' || echo 0)"
echo "tier-1 counts: passed=$passed failed=$failed errors=$errors"
tier1=0
if [ "$passed" -eq 0 ] && [ "$failed" -eq 0 ]; then
    echo "tier-1: could not parse pytest summary — treating as failure"
    tier1=1
elif [ "$errors" -gt 0 ]; then
    # collection/import errors mean tests never ran — never green
    echo "tier-1 REGRESSION: $errors collection/import error(s)"
    tier1=1
elif [ "$failed" -gt "$BASELINE" ]; then
    echo "tier-1 REGRESSION: $failed failures > baseline $BASELINE"
    tier1=1
elif [ "$passed" -lt "$PASS_FLOOR" ]; then
    # catches vanished/deselected tests that a failure count can't see
    echo "tier-1 REGRESSION: only $passed passed < floor $PASS_FLOOR"
    tier1=1
else
    echo "tier-1 OK: $failed failed (<=$BASELINE), $passed passed (>=$PASS_FLOOR)"
fi

echo "== sharded smoke: donated mesh step on 8 fake devices =="
# excluded from the tier-1 stage above (no double pay for the 8-fake-device
# subprocess compiles); the multi-device tests set their own XLA_FLAGS
python -m pytest tests/test_sharded_step.py -q
sharded=$?

echo "== benchmarks: validation (--fast) =="
python -m benchmarks.run --fast
bench=$?

echo "== benchmarks: kernel bench (--fast) =="
python -m benchmarks.kernel_bench --fast
kern=$?

echo "== benchmarks: step bench (--fast, writes BENCH_step.json) =="
# gate only on the bench RUNNING (a perf regression gate needs a second
# trajectory point first — the committed BENCH_step.json is that baseline)
python -m benchmarks.step_bench --fast
stepb=$?

echo "ci summary: tier1=$tier1 (passed=$passed failed=$failed baseline=$BASELINE) sharded=$sharded bench=$bench kernel_bench=$kern step_bench=$stepb"
for rc in $tier1 $sharded $bench $kern $stepb; do
    [ "$rc" -ne 0 ] && exit "$rc"
done
exit 0
