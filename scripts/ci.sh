#!/usr/bin/env bash
# CI entry point: tier-1 tests + fast benchmark validation + kernel bench.
#
#   bash scripts/ci.sh
#
# Runs everything even if an early stage fails (so one run collects every
# signal). Tier-1 gating is REGRESSION-based: we parse the pass/fail counts
# and fail the run if the failure count regresses past the baseline or the
# passed count drops below the floor. The seed snapshot shipped with 16
# known failures; PR 2 fixed 14 (dryrun mesh cells), PR 3 fixed the last 2
# (end-to-end loss plateau, hlo cost_analysis shape) — the suite is gated
# GREEN (0 failures) from PR 3 on.
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

BASELINE="${TIER1_BASELINE_FAILURES:-0}"
# floor excludes tests/test_sharded_step.py (8 tests) and
# tests/test_elastic_restart.py (11): each gates in its own dedicated stage
# below. PR 5 added tests/test_tape_residency.py (32) and
# tests/test_compression.py (10 without hypothesis): counted suite was 332
# when hypothesis is absent. PR 6 added tests/test_layer_scope.py (29) and
# 9 layer-scope cases in test_tape_residency: counted suite is 370. The
# elastic-restart PR added tests/test_fault_tolerance.py (14) and 5 ledger
# tests in test_accounting: counted suite is 389. The floor sits 4 below
# that because installing hypothesis REPLACES test_compression's 5
# parametrized fallback cases with 1 @given test (net -4 there, while
# unskipping test_ghost_properties adds tests) — the floor must not fail a
# fuller environment.
PASS_FLOOR="${TIER1_BASELINE_PASSED:-385}"
LOG="$(mktemp)"
trap 'rm -f "$LOG"' EXIT

echo "== tier-1: pytest (baseline: <=$BASELINE failed, >=$PASS_FLOOR passed) =="
# test_sharded_step and test_elastic_restart run in their own dedicated
# stages below — running their multi-minute subprocess fleets twice per CI
# pass is pure waste
python -m pytest -q --ignore=tests/test_sharded_step.py \
    --ignore=tests/test_elastic_restart.py 2>&1 | tee "$LOG"
failed="$(grep -oE '[0-9]+ failed' "$LOG" | tail -1 | grep -oE '[0-9]+' || echo 0)"
passed="$(grep -oE '[0-9]+ passed' "$LOG" | tail -1 | grep -oE '[0-9]+' || echo 0)"
errors="$(grep -oE '[0-9]+ errors?([, ]|$)' "$LOG" | tail -1 | grep -oE '[0-9]+' || echo 0)"
echo "tier-1 counts: passed=$passed failed=$failed errors=$errors"
tier1=0
if [ "$passed" -eq 0 ] && [ "$failed" -eq 0 ]; then
    echo "tier-1: could not parse pytest summary — treating as failure"
    tier1=1
elif [ "$errors" -gt 0 ]; then
    # collection/import errors mean tests never ran — never green
    echo "tier-1 REGRESSION: $errors collection/import error(s)"
    tier1=1
elif [ "$failed" -gt "$BASELINE" ]; then
    echo "tier-1 REGRESSION: $failed failures > baseline $BASELINE"
    tier1=1
elif [ "$passed" -lt "$PASS_FLOOR" ]; then
    # catches vanished/deselected tests that a failure count can't see
    echo "tier-1 REGRESSION: only $passed passed < floor $PASS_FLOOR"
    tier1=1
else
    echo "tier-1 OK: $failed failed (<=$BASELINE), $passed passed (>=$PASS_FLOOR)"
fi

echo "== sharded smoke: donated mesh step on 8 fake devices =="
# excluded from the tier-1 stage above (no double pay for the 8-fake-device
# subprocess compiles); the multi-device tests set their own XLA_FLAGS
python -m pytest tests/test_sharded_step.py -q
sharded=$?

echo "== layer-scope smoke: streamed one-pass backward through the CLI =="
# end-to-end through the real train driver: --clipping-scope layer re-scopes
# the policy to per-path clip units and the BK engine streams every tap
# (tests cover parity; this guards the CLI wiring + a real jit/compile)
python -m repro.launch.train --smoke --steps 3 --batch 4 --seq 16 \
    --clipping-scope layer --log-every 1
layer=$?

echo "== crash/resume smoke: train -> SIGKILL -> resume -> compare =="
# the gating restart-correctness demonstration, through the production CLI:
# an uninterrupted reference run, a run SIGKILLed mid-training (the
# fault-injection env channel), and a restart with the SAME command line.
# The resumed run must report bitwise-identical final params (sha256) and
# an identical epsilon — anything else means the restart re-drew noise or
# the ledger lost/double-counted accounted steps.
smoke_train() {
    python -m repro.launch.train --smoke --steps 6 --batch 4 --seq 16 \
        --lr 1e-3 --mode bk --policy "" --sigma 0.5 --log-every 100 "$@"
}
CR="$(mktemp -d)"
crash=0
smoke_train --out "$CR/ref.json" || crash=1
# subshell: an env-prefix on a bash FUNCTION call leaks the variable into
# the parent shell, which would crash the resume run below too
(export REPRO_FAULT="step@4:sigkill"
 smoke_train --ckpt-dir "$CR/ck" --ckpt-every 2 --out "$CR/na.json")
rc=$?
if [ "$rc" -ne 137 ]; then
    echo "crash run exited $rc, expected 137 (SIGKILL)"; crash=1
fi
smoke_train --ckpt-dir "$CR/ck" --ckpt-every 2 --out "$CR/resumed.json" \
    || crash=1
python scripts/compare_runs.py "$CR/ref.json" "$CR/resumed.json" || crash=1
rm -rf "$CR"

echo "== elastic restart: fault-injected subprocess suite =="
# the full acceptance matrix (SGD + FTRL bitwise resume, SIGTERM
# preemption, kill-mid-checkpoint-write, cross-device-count restore)
python -m pytest tests/test_elastic_restart.py -q
elastic=$?

echo "== benchmarks: validation (--fast) =="
python -m benchmarks.run --fast
bench=$?

echo "== benchmarks: kernel bench (--fast) =="
python -m benchmarks.kernel_bench --fast
kern=$?

echo "== benchmarks: step bench (--fast, writes BENCH_step.json, gated) =="
# GATES against the committed same-backend BENCH_step.json (per-cell tape
# policy recorded): per-device peak-HBM regression > 10% fails (memory is
# deterministic); tokens/s gets a wide 50% band here because 3-step CPU
# interpret-mode wall clocks jitter ~40% run-to-run with machine load —
# the wall gate is a catastrophe detector on CPU, the real throughput gate
# engages on accelerator backends (STEP_GATE=0 disables, STEP_GATE_TOL /
# STEP_GATE_TOKS_TOL tune). A failing gate keeps the committed file and
# writes BENCH_step.json.regressed for inspection.
STEP_GATE_TOKS_TOL="${STEP_GATE_TOKS_TOL:-0.5}" python -m benchmarks.step_bench --fast
stepb=$?

echo "ci summary: tier1=$tier1 (passed=$passed failed=$failed baseline=$BASELINE) sharded=$sharded layer_smoke=$layer crash_resume=$crash elastic=$elastic bench=$bench kernel_bench=$kern step_bench=$stepb"
for rc in $tier1 $sharded $layer $crash $elastic $bench $kern $stepb; do
    [ "$rc" -ne 0 ] && exit "$rc"
done
exit 0
