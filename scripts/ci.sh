#!/usr/bin/env bash
# CI entry point: tier-1 tests + fast benchmark validation + kernel bench.
#
#   bash scripts/ci.sh
#
# Runs everything even if an early stage fails (so one run collects every
# signal), then exits with the tier-1 status.
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -q
tier1=$?

echo "== benchmarks: validation (--fast) =="
python -m benchmarks.run --fast
bench=$?

echo "== benchmarks: kernel bench (--fast) =="
python -m benchmarks.kernel_bench --fast
kern=$?

echo "ci summary: tier1=$tier1 bench=$bench kernel_bench=$kern"
exit $(( tier1 != 0 ? tier1 : (bench != 0 ? bench : kern) ))
