"""Compare two train-run summaries (launch.train --out json) for
restart-exactness: the resumed run must have actually resumed, reach the
same final params BITWISE (sha256) and report the identical epsilon.

    python scripts/compare_runs.py ref.json resumed.json

Exit 0 on exact match; nonzero with a diagnosis otherwise. Used by the
ci.sh crash/resume gating stage."""
import json
import sys


def main(ref_path: str, got_path: str) -> int:
    with open(ref_path) as f:
        ref = json.load(f)
    with open(got_path) as f:
        got = json.load(f)
    problems = []
    if got.get("resumed_from", 0) <= 0:
        problems.append("resume never engaged (resumed_from="
                        f"{got.get('resumed_from')!r}) — the run restarted "
                        "from scratch, which proves nothing")
    if got["steps_done"] != ref["steps_done"]:
        problems.append(f"steps_done {got['steps_done']} != "
                        f"{ref['steps_done']}")
    if got["params_sha256"] != ref["params_sha256"]:
        problems.append("final params DIVERGED (sha256 "
                        f"{got['params_sha256'][:12]}... != "
                        f"{ref['params_sha256'][:12]}...) — the restart "
                        "re-drew noise or lost state")
    if got["epsilon"] != ref["epsilon"]:
        problems.append(f"epsilon DIVERGED ({got['epsilon']} != "
                        f"{ref['epsilon']}) — the ledger lost or "
                        "double-counted accounted steps")
    if problems:
        for p in problems:
            print(f"compare_runs: {p}", file=sys.stderr)
        return 1
    print("crash/resume smoke OK: bitwise params + identical epsilon "
          f"(eps={ref['epsilon']:.4f}, resumed_from={got['resumed_from']})")
    return 0


if __name__ == "__main__":
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    sys.exit(main(sys.argv[1], sys.argv[2]))
